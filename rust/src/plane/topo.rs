//! CPU topology discovery and thread pinning for the scheduling plane.
//!
//! The paper's throughput argument assumes schedulers run "in parallel on
//! multiple machines with minimum coordination"; inside one machine the
//! analogous discipline is *memory distance*: a frontend shard and the
//! workers it routes to should share a package (socket), and the shared
//! words they do exchange should never share a cache line with unrelated
//! traffic. This module supplies the three pieces, dependency-free:
//!
//! * **discovery** — [`CpuTopology::detect`] parses
//!   `/sys/devices/system/cpu/cpu*/topology/{physical_package_id,core_id}`
//!   on Linux. Any missing or garbage file (containers routinely hide or
//!   mangle sysfs) degrades to the flat single-package fallback built from
//!   [`std::thread::available_parallelism`] — discovery never fails and
//!   never panics;
//! * **pinning** — [`pin_current_thread`] is a raw `sched_setaffinity`
//!   syscall (inline asm on `x86_64`/`aarch64` Linux; the repo is std-only
//!   by policy, so no libc crate). On other OSes/arches it is a no-op
//!   returning `false`, and a denied syscall (seccomp) is reported the
//!   same way — callers treat pinning as best-effort;
//! * **placement** — [`PlacementPlan`] assigns shard and worker threads to
//!   CPUs (shards round-robin across packages, workers partitioned per
//!   package) and, under [`PinMode::Sockets`], hands each shard its
//!   same-package worker group so power-of-two probing prefers local cache
//!   lines and spills cross-socket only past a queue threshold
//!   ([`DEFAULT_SPILL_THRESHOLD`]).
//!
//! [`PinMode::None`] is the default and is bit-identical to the pre-pinning
//! plane: no topology is read, no thread is pinned, no RNG stream is
//! touched (pinned by `tests/determinism.rs`).

use std::path::Path;

/// Queue length above which a socket-local group decision spills to the
/// full cross-socket view. Small enough that a backed-up local group stops
/// hoarding work; large enough that transient one-task queues stay local.
pub const DEFAULT_SPILL_THRESHOLD: usize = 4;

/// How plane threads are placed on the CPU topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum PinMode {
    /// No pinning, no topology discovery — the pre-pinning plane,
    /// bit-identical decision streams.
    #[default]
    None,
    /// Pin shard and worker threads to CPUs (shards round-robin across
    /// packages, workers partitioned per package). Decisions unchanged.
    Cores,
    /// [`PinMode::Cores`] placement *plus* socket-local probing: each
    /// shard prefers its same-package worker group and spills cross-socket
    /// only when the local group is backed up.
    Sockets,
}

impl PinMode {
    /// CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            PinMode::None => "none",
            PinMode::Cores => "cores",
            PinMode::Sockets => "sockets",
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "none" => Ok(PinMode::None),
            "cores" => Ok(PinMode::Cores),
            "sockets" => Ok(PinMode::Sockets),
            other => Err(format!("unknown pin mode '{other}' (none | cores | sockets)")),
        }
    }
}

/// One logical CPU's position in the topology.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct CpuSlot {
    /// Logical CPU id (the `sched_setaffinity` bit).
    pub cpu: usize,
    /// Physical package (socket) id, renumbered densely from 0.
    pub package: usize,
    /// Core id within the package (SMT siblings share it).
    pub core: usize,
}

/// The machine's CPU topology: logical CPUs grouped into packages.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct CpuTopology {
    /// Every online logical CPU, sorted by CPU id.
    pub cpus: Vec<CpuSlot>,
    /// CPU ids per package, indexed by dense package id.
    pub package_cpus: Vec<Vec<usize>>,
}

impl CpuTopology {
    /// Discover the topology: sysfs on Linux, flat fallback anywhere the
    /// tree is absent or hostile. Never fails.
    pub fn detect() -> Self {
        Self::from_sysfs(Path::new("/sys/devices/system/cpu")).unwrap_or_else(Self::flat)
    }

    /// Flat single-package topology over `available_parallelism` CPUs (≥1).
    pub fn flat() -> Self {
        let n = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let cpus: Vec<CpuSlot> =
            (0..n).map(|i| CpuSlot { cpu: i, package: 0, core: i }).collect();
        Self { package_cpus: vec![(0..n).collect()], cpus }
    }

    /// Parse a sysfs CPU tree rooted at `root` (injectable so the fixture
    /// trees under `tests/fixtures/sysfs/` drive the parser in tests).
    /// Returns `None` — never panics — on any missing directory, missing
    /// file, or unparseable content: the caller falls back to [`flat`].
    ///
    /// [`flat`]: CpuTopology::flat
    pub fn from_sysfs(root: &Path) -> Option<Self> {
        let mut raw: Vec<(usize, usize, usize)> = Vec::new();
        for entry in std::fs::read_dir(root).ok()? {
            let entry = entry.ok()?;
            let name = entry.file_name();
            let name = name.to_str()?;
            let id = match name.strip_prefix("cpu") {
                // Only `cpu<digits>` entries are CPUs (`cpufreq`,
                // `cpuidle`, `possible`, ... share the directory).
                Some(d) if !d.is_empty() && d.bytes().all(|b| b.is_ascii_digit()) => {
                    d.parse::<usize>().ok()?
                }
                _ => continue,
            };
            let topo = entry.path().join("topology");
            let package = read_id(&topo.join("physical_package_id"))?;
            let core = read_id(&topo.join("core_id"))?;
            raw.push((id, package, core));
        }
        if raw.is_empty() {
            return None;
        }
        raw.sort_unstable();
        // Renumber packages densely in first-seen (= CPU-id) order so
        // package ids index `package_cpus` directly.
        let mut packages: Vec<usize> = Vec::new();
        let mut cpus = Vec::with_capacity(raw.len());
        let mut package_cpus: Vec<Vec<usize>> = Vec::new();
        for (cpu, pkg, core) in raw {
            let dense = match packages.iter().position(|&p| p == pkg) {
                Some(i) => i,
                None => {
                    packages.push(pkg);
                    package_cpus.push(Vec::new());
                    packages.len() - 1
                }
            };
            package_cpus[dense].push(cpu);
            cpus.push(CpuSlot { cpu, package: dense, core });
        }
        Some(Self { cpus, package_cpus })
    }

    /// Number of logical CPUs.
    pub fn n_cpus(&self) -> usize {
        self.cpus.len()
    }

    /// Number of packages (sockets).
    pub fn n_packages(&self) -> usize {
        self.package_cpus.len()
    }
}

/// Default poll-shard count for the net data plane: one shard per package
/// (each shard pins to its own socket, so the NIC-local package always
/// hosts one), capped at 4 — beyond that the shards outnumber the
/// connections' ability to keep them busy — and never more than the
/// connection count or fewer than 1.
pub fn default_poll_shards(topo: &CpuTopology, conns: usize) -> usize {
    topo.n_packages().min(4).min(conns.max(1)).max(1)
}

/// Read a small sysfs id file: trimmed non-negative integer or `None`.
fn read_id(path: &Path) -> Option<usize> {
    std::fs::read_to_string(path).ok()?.trim().parse::<usize>().ok()
}

/// Where every plane thread goes, plus the per-shard socket-local worker
/// groups. Built once before any thread spawns; `None` CPU slots mean
/// "leave this thread to the OS".
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlacementPlan {
    /// CPU per frontend shard (index = shard id).
    pub shard_cpus: Vec<Option<usize>>,
    /// CPU per worker thread (index = worker id).
    pub worker_cpus: Vec<Option<usize>>,
    /// Same-package worker ids per shard. Non-empty only under
    /// [`PinMode::Sockets`] with ≥ 2 packages — an empty group means the
    /// shard probes the full view exactly as before.
    pub shard_groups: Vec<Vec<usize>>,
}

impl PlacementPlan {
    /// The no-op plan: nothing pinned, no groups ([`PinMode::None`]).
    pub fn unpinned(shards: usize, workers: usize) -> Self {
        Self {
            shard_cpus: vec![None; shards],
            worker_cpus: vec![None; workers],
            shard_groups: vec![Vec::new(); shards],
        }
    }

    /// Place `shards` frontend threads and `workers` worker threads on
    /// `topo`: shard `s` goes to package `s % packages`, worker `w` to
    /// package `w % packages` (so each package hosts a balanced worker
    /// partition and every shard's package owns workers), and threads
    /// within a package rotate through its CPU list. Under
    /// [`PinMode::Sockets`] each shard also gets its same-package worker
    /// ids as its local probe group.
    pub fn new(mode: PinMode, topo: &CpuTopology, shards: usize, workers: usize) -> Self {
        if mode == PinMode::None || topo.n_cpus() == 0 {
            return Self::unpinned(shards, workers);
        }
        let packages = topo.n_packages();
        // Per-package rotating cursor: shards claim CPUs first, workers
        // continue from where the shards left off, wrapping as needed.
        let mut cursor = vec![0usize; packages];
        let mut take = |pkg: usize| {
            let cpus = &topo.package_cpus[pkg];
            let cpu = cpus[cursor[pkg] % cpus.len()];
            cursor[pkg] += 1;
            Some(cpu)
        };
        let shard_cpus: Vec<Option<usize>> = (0..shards).map(|s| take(s % packages)).collect();
        let worker_cpus: Vec<Option<usize>> = (0..workers).map(|w| take(w % packages)).collect();
        let shard_groups: Vec<Vec<usize>> = (0..shards)
            .map(|s| {
                if mode == PinMode::Sockets && packages >= 2 {
                    (0..workers).filter(|w| w % packages == s % packages).collect()
                } else {
                    Vec::new()
                }
            })
            .collect();
        Self { shard_cpus, worker_cpus, shard_groups }
    }
}

/// Pin the calling thread to logical CPU `cpu` via a raw
/// `sched_setaffinity(0, …)` syscall. Returns whether the kernel accepted
/// the mask — `false` on non-Linux builds, unsupported architectures,
/// out-of-range CPUs, and denied syscalls (containers). Never panics:
/// pinning is an optimization, not a correctness requirement.
#[cfg(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64")))]
pub fn pin_current_thread(cpu: usize) -> bool {
    // 16 × u64 = 1024 CPUs, the kernel's historical CPU_SETSIZE.
    let mut mask = [0u64; 16];
    if cpu >= mask.len() * 64 {
        return false;
    }
    mask[cpu / 64] = 1u64 << (cpu % 64);
    let ret = unsafe { sched_setaffinity(0, std::mem::size_of_val(&mask), mask.as_ptr()) };
    ret == 0
}

/// Portable fallback: pinning unavailable, report "not pinned".
#[cfg(not(all(target_os = "linux", any(target_arch = "x86_64", target_arch = "aarch64"))))]
pub fn pin_current_thread(_cpu: usize) -> bool {
    false
}

/// Raw `sched_setaffinity` (syscall 203), x86_64 Linux ABI: number in
/// `rax`, args in `rdi`/`rsi`/`rdx`, `rcx`/`r11` clobbered by `syscall`.
#[cfg(all(target_os = "linux", target_arch = "x86_64"))]
unsafe fn sched_setaffinity(pid: usize, len: usize, mask: *const u64) -> isize {
    let ret: isize;
    core::arch::asm!(
        "syscall",
        inlateout("rax") 203isize => ret,
        in("rdi") pid,
        in("rsi") len,
        in("rdx") mask,
        lateout("rcx") _,
        lateout("r11") _,
        options(nostack),
    );
    ret
}

/// Raw `sched_setaffinity` (syscall 122), aarch64 Linux ABI: number in
/// `x8`, args in `x0`–`x2`, return in `x0`.
#[cfg(all(target_os = "linux", target_arch = "aarch64"))]
unsafe fn sched_setaffinity(pid: usize, len: usize, mask: *const u64) -> isize {
    let ret: isize;
    core::arch::asm!(
        "svc #0",
        in("x8") 122usize,
        inlateout("x0") pid => ret,
        in("x1") len,
        in("x2") mask,
        options(nostack),
    );
    ret
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn fixture(name: &str) -> PathBuf {
        Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures/sysfs").join(name)
    }

    #[test]
    fn one_socket_fixture_parses() {
        let topo = CpuTopology::from_sysfs(&fixture("one_socket")).expect("clean tree parses");
        assert_eq!(topo.n_cpus(), 4);
        assert_eq!(topo.n_packages(), 1);
        assert_eq!(topo.package_cpus[0], vec![0, 1, 2, 3]);
        for (i, c) in topo.cpus.iter().enumerate() {
            assert_eq!(c.cpu, i);
            assert_eq!(c.package, 0);
            assert_eq!(c.core, i);
        }
    }

    #[test]
    fn two_socket_smt_fixture_parses_with_dense_packages() {
        let topo = CpuTopology::from_sysfs(&fixture("two_socket_smt")).expect("smt tree parses");
        assert_eq!(topo.n_cpus(), 8);
        assert_eq!(topo.n_packages(), 2);
        // Fixture writes raw package ids 3 and 7 — renumbered densely in
        // CPU-id order.
        assert_eq!(topo.package_cpus[0], vec![0, 1, 2, 3]);
        assert_eq!(topo.package_cpus[1], vec![4, 5, 6, 7]);
        // SMT siblings share a core id within the package.
        assert_eq!(topo.cpus[0].core, topo.cpus[2].core);
        assert_eq!(topo.cpus[1].core, topo.cpus[3].core);
        assert_ne!(topo.cpus[0].core, topo.cpus[1].core);
    }

    #[test]
    fn hostile_fixture_falls_back_without_panicking() {
        // Garbage package file, a cpu with no topology dir, a non-CPU
        // entry: the parser must return None — never panic — so detect()
        // degrades to the flat fallback.
        assert_eq!(CpuTopology::from_sysfs(&fixture("hostile")), None);
        assert_eq!(CpuTopology::from_sysfs(&fixture("does_not_exist")), None);
    }

    #[test]
    fn flat_fallback_is_one_package_over_available_parallelism() {
        let topo = CpuTopology::flat();
        assert!(topo.n_cpus() >= 1);
        assert_eq!(topo.n_packages(), 1);
        assert_eq!(topo.package_cpus[0].len(), topo.n_cpus());
        // detect() never fails, whatever this machine's sysfs looks like.
        let detected = CpuTopology::detect();
        assert!(detected.n_cpus() >= 1 && detected.n_packages() >= 1);
    }

    #[test]
    fn pin_mode_parses_and_round_trips() {
        for mode in [PinMode::None, PinMode::Cores, PinMode::Sockets] {
            assert_eq!(PinMode::parse(mode.name()), Ok(mode));
        }
        assert!(PinMode::parse("numa").is_err());
        assert_eq!(PinMode::default(), PinMode::None);
    }

    #[test]
    fn unpinned_plan_pins_nothing_and_groups_nothing() {
        let topo = CpuTopology::from_sysfs(&fixture("two_socket_smt")).unwrap();
        let plan = PlacementPlan::new(PinMode::None, &topo, 2, 8);
        assert_eq!(plan, PlacementPlan::unpinned(2, 8));
        assert!(plan.shard_cpus.iter().all(Option::is_none));
        assert!(plan.worker_cpus.iter().all(Option::is_none));
        assert!(plan.shard_groups.iter().all(Vec::is_empty));
    }

    #[test]
    fn cores_plan_spreads_shards_across_packages_without_groups() {
        let topo = CpuTopology::from_sysfs(&fixture("two_socket_smt")).unwrap();
        let plan = PlacementPlan::new(PinMode::Cores, &topo, 2, 4);
        // Shard 0 → package 0, shard 1 → package 1.
        assert_eq!(plan.shard_cpus, vec![Some(0), Some(4)]);
        // Workers alternate packages, continuing each package's cursor.
        assert_eq!(plan.worker_cpus, vec![Some(1), Some(5), Some(2), Some(6)]);
        // Cores mode never partitions probing.
        assert!(plan.shard_groups.iter().all(Vec::is_empty));
    }

    #[test]
    fn sockets_plan_partitions_workers_into_local_groups() {
        let topo = CpuTopology::from_sysfs(&fixture("two_socket_smt")).unwrap();
        let plan = PlacementPlan::new(PinMode::Sockets, &topo, 2, 6);
        assert_eq!(plan.shard_groups, vec![vec![0, 2, 4], vec![1, 3, 5]]);
        // The groups partition the worker set: disjoint and exhaustive, so
        // no worker is unreachable and none is double-owned.
        let mut seen: Vec<usize> = plan.shard_groups.concat();
        seen.sort_unstable();
        assert_eq!(seen, (0..6).collect::<Vec<_>>());
        // Every shard's group lives on the shard's own package.
        for (s, group) in plan.shard_groups.iter().enumerate() {
            let shard_pkg = topo.cpus[plan.shard_cpus[s].unwrap()].package;
            for &w in group {
                let worker_pkg = topo.cpus[plan.worker_cpus[w].unwrap()].package;
                assert_eq!(worker_pkg, shard_pkg, "shard {s} group strays off-package");
            }
        }
    }

    #[test]
    fn sockets_plan_on_one_package_degrades_to_ungrouped() {
        // One package ⇒ "local" would be everything: keep the standard
        // full-view probe path instead of a pointless indirection.
        let topo = CpuTopology::from_sysfs(&fixture("one_socket")).unwrap();
        let plan = PlacementPlan::new(PinMode::Sockets, &topo, 2, 4);
        assert!(plan.shard_groups.iter().all(Vec::is_empty));
        assert!(plan.shard_cpus.iter().all(Option::is_some));
    }

    #[test]
    fn more_threads_than_cpus_wraps_instead_of_panicking() {
        let topo = CpuTopology::from_sysfs(&fixture("one_socket")).unwrap();
        let plan = PlacementPlan::new(PinMode::Cores, &topo, 3, 16);
        assert!(plan.shard_cpus.iter().chain(&plan.worker_cpus).all(|c| c.unwrap() < 4));
    }

    #[test]
    fn pinning_never_panics_and_out_of_range_is_rejected() {
        // The syscall may be denied (containers) — both outcomes are
        // legal; what matters is no panic and an honest bool.
        let _ = pin_current_thread(0);
        assert!(!pin_current_thread(1 << 20));
    }
}
