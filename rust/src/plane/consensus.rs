//! Estimate-sync consensus for per-shard learners (§5, "Distributed
//! scheduler").
//!
//! With `--learners per-shard` every frontend owns a private
//! [`PerfLearner`](crate::learner::PerfLearner) fed by its own completion
//! channel. Cross-scheduler coordination is exactly what the paper
//! prescribes: "schedulers need only synchronize the estimates of worker
//! speeds regularly". Each shard exports an [`EstimateView`] snapshot of
//! its learner at its local publish cadence (into [`SharedViews`], a
//! per-shard mutex slot — never touched on the decision hot path); the sync
//! thread wakes every `sync_interval`, merges the views with
//! [`merge_estimates_into`], and publishes the consensus through the
//! seqlock [`EstimateTable`] all frontends read. The decision path stays
//! lock-free: frontends see new consensus exactly the way they always saw
//! aggregator publishes — one epoch probe per decision.

use super::state::EstimateTable;
use crate::learner::{merge_estimates_into, EstimateView};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-shard learner-view slots: shard `s` overwrites slot `s` at its local
/// publish cadence; the sync thread reads every slot at consensus epochs.
/// A mutex per slot is fine here — both sides touch it a few times per
/// second, never per decision.
#[derive(Debug)]
pub struct SharedViews {
    slots: Vec<Mutex<Vec<EstimateView>>>,
}

impl SharedViews {
    /// Slots for `shards` schedulers over `n` workers, initialized to the
    /// prior with zero weight (= "no knowledge yet", merges to the prior).
    pub fn new(shards: usize, n: usize, prior: f64) -> Self {
        assert!(shards > 0 && n > 0, "views need at least one shard and one worker");
        let init = vec![EstimateView { mu_hat: prior, samples: 0 }; n];
        Self { slots: (0..shards).map(|_| Mutex::new(init.clone())).collect() }
    }

    /// Number of shard slots.
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Replace shard `s`'s exported view.
    pub fn store(&self, s: usize, views: &[EstimateView]) {
        let mut slot = self.slots[s].lock().expect("view slot poisoned");
        slot.clear();
        slot.extend_from_slice(views);
    }

    /// Copy every shard's current view into `out` (buffers reused).
    pub fn collect_into(&self, out: &mut Vec<Vec<EstimateView>>) {
        out.resize_with(self.slots.len(), Vec::new);
        for (slot, buf) in self.slots.iter().zip(out.iter_mut()) {
            let v = slot.lock().expect("view slot poisoned");
            buf.clear();
            buf.extend_from_slice(&v);
        }
    }
}

/// Sum of the shards' f64-bit λ̂ slots (the plane's aggregate arrival
/// estimate).
pub(crate) fn lambda_total(slots: &[Arc<AtomicU64>]) -> f64 {
    slots.iter().map(|s| f64::from_bits(s.load(Ordering::Relaxed))).sum()
}

/// One consensus epoch: collect every shard's exported view, merge, publish
/// through the seqlock table. Factored out of the thread loop so tests can
/// drive epochs deterministically.
pub(crate) fn consensus_step(
    views: &SharedViews,
    table: &EstimateTable,
    lambda_slots: &[Arc<AtomicU64>],
    prior: f64,
    view_buf: &mut Vec<Vec<EstimateView>>,
    consensus: &mut [f64],
) {
    views.collect_into(view_buf);
    merge_estimates_into(view_buf, prior, consensus);
    table.publish(consensus, lambda_total(lambda_slots));
}

/// State moved into the sync thread.
pub(crate) struct SyncRun {
    pub views: Arc<SharedViews>,
    pub table: Arc<EstimateTable>,
    pub lambda_slots: Vec<Arc<AtomicU64>>,
    pub stop: Arc<AtomicBool>,
    pub sync_interval: f64,
    pub prior: f64,
    pub start: Instant,
}

/// The sync thread body: the plane's only estimate-table writer in
/// per-shard mode. Returns the number of consensus epochs published,
/// including the final drain-time epoch (which runs after every shard has
/// exported its final view, so the table ends as the consensus of the
/// drain-time views).
pub(crate) fn run_sync(ctx: SyncRun) -> u64 {
    let mut view_buf: Vec<Vec<EstimateView>> = Vec::new();
    let mut consensus = vec![0.0; ctx.table.n()];
    let mut epochs = 0u64;
    let mut next_sync = ctx.start + Duration::from_secs_f64(ctx.sync_interval);
    while !ctx.stop.load(Ordering::Acquire) {
        if Instant::now() >= next_sync {
            consensus_step(
                &ctx.views,
                &ctx.table,
                &ctx.lambda_slots,
                ctx.prior,
                &mut view_buf,
                &mut consensus,
            );
            epochs += 1;
            next_sync += Duration::from_secs_f64(ctx.sync_interval);
        } else {
            let wait = next_sync.saturating_duration_since(Instant::now());
            std::thread::sleep(wait.min(Duration::from_millis(5)));
        }
    }
    consensus_step(
        &ctx.views,
        &ctx.table,
        &ctx.lambda_slots,
        ctx.prior,
        &mut view_buf,
        &mut consensus,
    );
    epochs + 1
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::merge_estimates;

    fn v(mu: f64, s: u64) -> EstimateView {
        EstimateView { mu_hat: mu, samples: s }
    }

    #[test]
    fn fresh_slots_merge_to_the_prior() {
        let views = SharedViews::new(3, 2, 0.75);
        assert_eq!(views.shards(), 3);
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        assert_eq!(merge_estimates(&buf, 0.75), vec![0.75, 0.75]);
    }

    #[test]
    fn store_overwrites_one_slot_only() {
        let views = SharedViews::new(2, 2, 1.0);
        views.store(1, &[v(2.0, 10), v(0.5, 4)]);
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        assert_eq!(buf[0], vec![v(1.0, 0), v(1.0, 0)]);
        assert_eq!(buf[1], vec![v(2.0, 10), v(0.5, 4)]);
    }

    #[test]
    fn consensus_step_publishes_the_merge_of_exported_views() {
        let views = SharedViews::new(2, 2, 1.0);
        views.store(0, &[v(2.0, 40), v(0.0, 0)]);
        views.store(1, &[v(1.0, 10), v(0.5, 5)]);
        let table = EstimateTable::new(2, 1.0);
        let lambda_slots: Vec<Arc<AtomicU64>> =
            (0..2).map(|i| Arc::new(AtomicU64::new((i as f64 * 3.0).to_bits()))).collect();
        let e0 = table.epoch();
        let mut buf = Vec::new();
        let mut consensus = vec![0.0; 2];
        consensus_step(&views, &table, &lambda_slots, 1.0, &mut buf, &mut consensus);
        assert_eq!(table.epoch(), e0 + 2, "each consensus step is one seqlock publish");
        let (mu, lambda) = table.snapshot();
        // Bit-exact agreement with the library merge rule at every epoch.
        let expect = merge_estimates(&buf, 1.0);
        assert_eq!(mu, expect);
        assert!((mu[0] - 1.8).abs() < 1e-12);
        assert_eq!(mu[1], 0.5);
        assert_eq!(lambda, 3.0);
    }
}
