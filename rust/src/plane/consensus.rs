//! Estimate-sync consensus for per-shard learners (§5, "Distributed
//! scheduler").
//!
//! With `--learners per-shard` every frontend owns a private
//! [`PerfLearner`](crate::learner::PerfLearner) fed by its own completion
//! channel. Cross-scheduler coordination is exactly what the paper
//! prescribes: "schedulers need only synchronize the estimates of worker
//! speeds regularly". Each shard exports a [`SyncPayload`] snapshot of its
//! learner — per-worker [`EstimateView`]s plus its local arrival share λ̂ₛ —
//! at its local publish cadence (into [`SharedViews`], a per-shard mutex
//! slot — never touched on the decision hot path). The sync thread runs a
//! [`SyncPolicy`]:
//!
//! * **periodic** — every check epoch collects all slots, merges with
//!   [`merge_payloads_into`] (λ̂_global = Σ exchanged shares), and publishes
//!   through the seqlock [`EstimateTable`] — the original behavior;
//! * **adaptive** — shards flag divergence at export time
//!   ([`SharedViews::request_merge`], set when a shard's local estimates
//!   drift beyond the relative-error threshold from its last adopted
//!   consensus); the sync thread merges only on a flagged request past the
//!   minimum spacing, or when the staleness deadline forces it. Skipped
//!   epochs cost zero slot locks and zero publishes;
//! * **gossip** — each round merges one deterministic-RNG *pairing* of
//!   shard slots (two view collections per publish instead of k). The
//!   plane has a single estimate table, so unlike the DES engine's true
//!   pairwise adoption, every frontend adopts each published pair merge —
//!   in-process gossip reduces per-epoch collection cost, not adoption
//!   fan-out.
//!
//! The drain-time epoch is always a full merge, so the reported estimates
//! are the consensus of every shard's final view regardless of policy. The
//! decision path stays lock-free: frontends see new consensus exactly the
//! way they always saw aggregator publishes — one epoch probe per decision.

use super::state::{CachePadded, EstimateTable};
use crate::learner::{
    divergence_of, merge_estimates_into, merge_payloads_into, EstimateView, SyncDecision,
    SyncPayload, SyncPolicy,
};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

/// Per-shard sync-payload slots: shard `s` overwrites slot `s` at its local
/// publish cadence; the sync thread reads slots at consensus epochs. A
/// mutex per slot is fine here — both sides touch it a few times per
/// second, never per decision. Dirty flags record which slots changed since
/// the last collection, and a shared merge-request flag carries shard-side
/// divergence triggers to the adaptive policy.
/// Slots and dirty flags are per-scheduler cache-padded: shard `s` writes
/// only its own slot, and padding keeps one shard's export from bouncing
/// the line under a neighbor's mutex word or dirty flag.
#[derive(Debug)]
pub struct SharedViews {
    slots: Vec<CachePadded<Mutex<SyncPayload>>>,
    /// Slot re-exported since the last collection — the sync thread skips
    /// a check epoch outright when nothing is dirty (merging unchanged
    /// views would only republish identical state).
    dirty: Vec<CachePadded<AtomicBool>>,
    /// Some shard's export diverged beyond the adaptive threshold: it
    /// requests a merge at the next policy check.
    merge_requested: AtomicBool,
}

impl SharedViews {
    /// Slots for `shards` schedulers over `n` workers, initialized to the
    /// prior with zero weight (= "no knowledge yet", merges to the prior)
    /// and a zero arrival share.
    pub fn new(shards: usize, n: usize, prior: f64) -> Self {
        assert!(shards > 0 && n > 0, "views need at least one shard and one worker");
        let init = SyncPayload {
            views: vec![EstimateView { mu_hat: prior, samples: 0 }; n],
            lambda_hat: 0.0,
        };
        Self {
            slots: (0..shards).map(|_| CachePadded::new(Mutex::new(init.clone()))).collect(),
            dirty: (0..shards).map(|_| CachePadded::new(AtomicBool::new(false))).collect(),
            merge_requested: AtomicBool::new(false),
        }
    }

    /// Number of shard slots.
    pub fn shards(&self) -> usize {
        self.slots.len()
    }

    /// Replace shard `s`'s exported payload: its estimate views plus its
    /// local arrival share λ̂ₛ.
    pub fn store(&self, s: usize, views: &[EstimateView], lambda_hat: f64) {
        let mut slot = self.slots[s].lock().expect("view slot poisoned");
        slot.views.clear();
        slot.views.extend_from_slice(views);
        slot.lambda_hat = lambda_hat;
        self.dirty[s].store(true, Ordering::Release);
    }

    /// A shard's local estimates diverged beyond the adaptive threshold:
    /// ask the sync thread to merge at its next check epoch.
    pub fn request_merge(&self) {
        self.merge_requested.store(true, Ordering::Release);
    }

    /// Consume the pending merge request, if any.
    pub fn take_merge_request(&self) -> bool {
        self.merge_requested.swap(false, Ordering::AcqRel)
    }

    /// Whether any slot was re-exported since the last collection.
    pub fn any_dirty(&self) -> bool {
        self.dirty.iter().any(|d| d.load(Ordering::Acquire))
    }

    /// Copy every shard's current payload into `out` (buffers reused) and
    /// clear the dirty flags.
    pub fn collect_into(&self, out: &mut Vec<SyncPayload>) {
        out.resize_with(self.slots.len(), SyncPayload::default);
        for ((slot, dirty), buf) in self.slots.iter().zip(self.dirty.iter()).zip(out.iter_mut()) {
            let p = slot.lock().expect("view slot poisoned");
            buf.views.clear();
            buf.views.extend_from_slice(&p.views);
            buf.lambda_hat = p.lambda_hat;
            dirty.store(false, Ordering::Release);
        }
    }

    /// Copy just shards `a` and `b` into `out` (a gossip pair), clearing
    /// their dirty flags.
    pub fn collect_pair_into(&self, a: usize, b: usize, out: &mut Vec<SyncPayload>) {
        out.resize_with(2, SyncPayload::default);
        for (s, buf) in [a, b].into_iter().zip(out.iter_mut()) {
            let p = self.slots[s].lock().expect("view slot poisoned");
            buf.views.clear();
            buf.views.extend_from_slice(&p.views);
            buf.lambda_hat = p.lambda_hat;
            self.dirty[s].store(false, Ordering::Release);
        }
    }

    /// λ̂_global: the sum of every shard's exported arrival share (scalar
    /// reads only — cheap enough for every gossip publish).
    pub fn lambda_total(&self) -> f64 {
        self.slots.iter().map(|s| s.lock().expect("view slot poisoned").lambda_hat).sum()
    }
}

/// Sum of the shards' f64-bit λ̂ slots. Used by the *shared-learner*
/// aggregator, which has no payload exchange (shards publish their live λ̂
/// into atomic slots per decision); per-shard consensus reads λ̂ from the
/// exchanged [`SyncPayload`]s instead.
pub(crate) fn lambda_total(slots: &[Arc<AtomicU64>]) -> f64 {
    slots.iter().map(|s| f64::from_bits(s.load(Ordering::Relaxed))).sum()
}

/// One all-to-all consensus epoch: collect every shard's exported payload,
/// merge views, sum λ̂ shares, publish through the seqlock table. Factored
/// out of the thread loop so tests can drive epochs deterministically.
pub(crate) fn consensus_step(
    views: &SharedViews,
    table: &EstimateTable,
    prior: f64,
    payload_buf: &mut Vec<SyncPayload>,
    consensus: &mut [f64],
) {
    views.collect_into(payload_buf);
    let lambda = merge_payloads_into(payload_buf, prior, consensus);
    table.publish(consensus, lambda);
}

/// One gossip pair merge: merge shards `a` and `b`'s views, publish the
/// pair consensus with `lambda` — the plane-wide λ̂, computed once per
/// round by the caller rather than re-locking every slot per pair.
pub(crate) fn pair_step(
    views: &SharedViews,
    table: &EstimateTable,
    prior: f64,
    pair: (usize, usize),
    lambda: f64,
    pair_buf: &mut Vec<SyncPayload>,
    consensus: &mut [f64],
) {
    views.collect_pair_into(pair.0, pair.1, pair_buf);
    merge_estimates_into(pair_buf, prior, consensus);
    table.publish(consensus, lambda);
}

/// State moved into the sync thread.
pub(crate) struct SyncRun {
    pub views: Arc<SharedViews>,
    pub table: Arc<EstimateTable>,
    pub stop: Arc<AtomicBool>,
    pub policy: SyncPolicy,
    pub prior: f64,
    pub start: Instant,
    /// Metrics registry: the sync thread bumps `sync_epochs` / `sync_merges`
    /// as it goes (off the decision path — a few writes per second).
    pub obs: Arc<crate::obs::Registry>,
    /// Optional flight recorder: every merge lands a
    /// [`FlightEvent::Consensus`](crate::obs::FlightEvent) in the consensus
    /// lane (policy, consensus shift, views merged, epochs since the last
    /// merge).
    pub flight: Option<Arc<crate::obs::FlightRecorder>>,
}

/// What the sync thread hands back at drain.
#[derive(Debug, Clone, Copy)]
pub(crate) struct SyncOutcome {
    /// Consensus publishes + skipped checks, including the final
    /// drain-time epoch.
    pub epochs: u64,
    /// Merge operations performed (all-to-all = 1, each gossip pair = 1),
    /// including the final drain-time merge.
    pub merges: u64,
}

/// The sync thread body: the plane's only estimate-table writer in
/// per-shard mode. The final drain-time epoch runs after every shard has
/// exported its final view, and is always a full merge, so the table ends
/// as the consensus of the drain-time views under every policy.
pub(crate) fn run_sync(mut ctx: SyncRun) -> SyncOutcome {
    let mut payload_buf: Vec<SyncPayload> = Vec::new();
    let mut pair_buf: Vec<SyncPayload> = Vec::new();
    let mut consensus = vec![0.0; ctx.table.n()];
    // Previous published consensus, kept so each merge's flight event can
    // report how far the consensus actually moved (relative shift via
    // [`divergence_of`]). Starts at the prior — the table's initial state.
    let mut last_consensus = vec![ctx.prior; ctx.table.n()];
    // Check epochs elapsed since the last merge (the "how stale was the
    // consensus when we finally merged" signal for adaptive policies).
    let mut epoch_lag: u64 = 0;
    let policy_name = ctx.policy.kind().name();
    let check = Duration::from_secs_f64(ctx.policy.check_interval());
    let mut next_check = ctx.start + check;
    while !ctx.stop.load(Ordering::Acquire) {
        if Instant::now() >= next_check {
            // Nothing re-exported since the last collection: re-merging
            // would republish identical state and force every frontend
            // through a pointless table re-read + sampler rebuild. Skip
            // the epoch entirely (export always precedes a merge request,
            // so no pending request can be lost here).
            if !ctx.views.any_dirty() {
                next_check += check;
                continue;
            }
            let now_s = ctx.start.elapsed().as_secs_f64();
            let diverged = ctx.views.take_merge_request();
            ctx.obs.sync_epochs.inc();
            match ctx.policy.on_epoch(now_s, diverged) {
                SyncDecision::Skip => {
                    epoch_lag += 1;
                    if diverged {
                        // The policy deferred a shard's divergence trigger
                        // (min-interval suppression): re-raise it so the
                        // request survives to the next check epoch instead
                        // of being silently dropped.
                        ctx.views.request_merge();
                    }
                }
                SyncDecision::MergeAll => {
                    consensus_step(
                        &ctx.views,
                        &ctx.table,
                        ctx.prior,
                        &mut payload_buf,
                        &mut consensus,
                    );
                    ctx.obs.sync_merges.inc();
                    record_merge(
                        &ctx,
                        policy_name,
                        &consensus,
                        &mut last_consensus,
                        ctx.views.shards() as u32,
                        epoch_lag,
                    );
                    epoch_lag = 0;
                }
                SyncDecision::MergePairs(pairs) => {
                    // One plane-wide λ̂ per round, shared by every pair
                    // publish.
                    let lambda = ctx.views.lambda_total();
                    for pair in pairs {
                        pair_step(
                            &ctx.views,
                            &ctx.table,
                            ctx.prior,
                            pair,
                            lambda,
                            &mut pair_buf,
                            &mut consensus,
                        );
                        ctx.obs.sync_merges.inc();
                        record_merge(
                            &ctx,
                            policy_name,
                            &consensus,
                            &mut last_consensus,
                            2,
                            epoch_lag,
                        );
                        epoch_lag = 0;
                    }
                }
            }
            next_check += check;
        } else {
            let wait = next_check.saturating_duration_since(Instant::now());
            std::thread::sleep(wait.min(Duration::from_millis(5)));
        }
    }
    // Drain-time epoch: always a full merge of the final views.
    consensus_step(&ctx.views, &ctx.table, ctx.prior, &mut payload_buf, &mut consensus);
    ctx.obs.sync_epochs.inc();
    ctx.obs.sync_merges.inc();
    record_merge(
        &ctx,
        policy_name,
        &consensus,
        &mut last_consensus,
        ctx.views.shards() as u32,
        epoch_lag,
    );
    SyncOutcome { epochs: ctx.policy.epochs() + 1, merges: ctx.policy.merges() + 1 }
}

/// Flight-record one consensus merge: how far the published consensus
/// moved relative to the previous publish, how many views went into it,
/// and how many check epochs the plane sat on a stale consensus first.
/// `last` is updated to the new consensus. No-op without a recorder.
fn record_merge(
    ctx: &SyncRun,
    policy: &'static str,
    consensus: &[f64],
    last: &mut [f64],
    views: u32,
    epoch_lag: u64,
) {
    // Mirror the published consensus into the registry gauges — the scrape
    // endpoint's μ̂/λ̂ view of a per-shard plane.
    ctx.obs.set_mu_hat(consensus);
    ctx.obs.lambda_hat.set(ctx.table.lambda());
    ctx.obs.publishes.inc();
    if let Some(rec) = ctx.flight.as_deref() {
        let shift = divergence_of(consensus, last);
        rec.record_consensus(crate::obs::FlightEvent::Consensus {
            t_ns: ctx.start.elapsed().as_nanos() as u64,
            policy,
            epoch: ctx.policy.epochs(),
            divergence: shift,
            views,
            epoch_lag,
        });
    }
    last.copy_from_slice(consensus);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::learner::merge_estimates;

    fn v(mu: f64, s: u64) -> EstimateView {
        EstimateView { mu_hat: mu, samples: s }
    }

    #[test]
    fn fresh_slots_merge_to_the_prior() {
        let views = SharedViews::new(3, 2, 0.75);
        assert_eq!(views.shards(), 3);
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        let mut out = vec![0.0; 2];
        let lambda = merge_payloads_into(&buf, 0.75, &mut out);
        assert_eq!(out, vec![0.75, 0.75]);
        assert_eq!(lambda, 0.0, "no shard has exported an arrival share yet");
    }

    #[test]
    fn store_overwrites_one_slot_only() {
        let views = SharedViews::new(2, 2, 1.0);
        views.store(1, &[v(2.0, 10), v(0.5, 4)], 7.5);
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        assert_eq!(buf[0].views, vec![v(1.0, 0), v(1.0, 0)]);
        assert_eq!(buf[0].lambda_hat, 0.0);
        assert_eq!(buf[1].views, vec![v(2.0, 10), v(0.5, 4)]);
        assert_eq!(buf[1].lambda_hat, 7.5);
    }

    #[test]
    fn dirty_flags_track_exports_and_collections() {
        let views = SharedViews::new(2, 1, 1.0);
        assert!(!views.any_dirty());
        views.store(0, &[v(2.0, 3)], 1.0);
        assert!(views.any_dirty());
        let mut buf = Vec::new();
        views.collect_into(&mut buf);
        assert!(!views.any_dirty(), "collection must clear the dirty flags");
    }

    #[test]
    fn merge_requests_are_consumed_once() {
        let views = SharedViews::new(2, 1, 1.0);
        assert!(!views.take_merge_request());
        views.request_merge();
        views.request_merge(); // idempotent
        assert!(views.take_merge_request());
        assert!(!views.take_merge_request(), "request must not replay");
    }

    #[test]
    fn consensus_step_publishes_the_merge_of_exported_views() {
        let views = SharedViews::new(2, 2, 1.0);
        views.store(0, &[v(2.0, 40), v(0.0, 0)], 0.0);
        views.store(1, &[v(1.0, 10), v(0.5, 5)], 3.0);
        let table = EstimateTable::new(2, 1.0);
        let e0 = table.epoch();
        let mut buf = Vec::new();
        let mut consensus = vec![0.0; 2];
        consensus_step(&views, &table, 1.0, &mut buf, &mut consensus);
        assert_eq!(table.epoch(), e0 + 2, "each consensus step is one seqlock publish");
        let (mu, lambda) = table.snapshot();
        // Bit-exact agreement with the library merge rule at every epoch.
        let expect = merge_estimates(&buf, 1.0);
        assert_eq!(mu, expect);
        assert!((mu[0] - 1.8).abs() < 1e-12);
        assert_eq!(mu[1], 0.5);
        // λ̂_global is the sum of the *exchanged* shares.
        assert_eq!(lambda, 3.0);
    }

    #[test]
    fn pair_step_merges_two_slots_with_the_plane_wide_lambda() {
        let views = SharedViews::new(3, 1, 1.0);
        views.store(0, &[v(3.0, 30)], 4.0);
        views.store(1, &[v(1.0, 10)], 1.0);
        views.store(2, &[v(9.0, 99)], 2.0);
        let table = EstimateTable::new(1, 1.0);
        let mut pair_buf = Vec::new();
        let mut consensus = vec![0.0; 1];
        let lambda = views.lambda_total();
        pair_step(&views, &table, 1.0, (0, 1), lambda, &mut pair_buf, &mut consensus);
        let (mu, lambda) = table.snapshot();
        // Shard 2's view is not in the pair merge...
        assert!((mu[0] - (3.0 * 30.0 + 10.0) / 40.0).abs() < 1e-12, "{mu:?}");
        // ...but its λ̂ share still counts toward the plane-wide estimate.
        assert_eq!(lambda, 7.0);
    }
}
