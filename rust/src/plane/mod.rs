//! The sharded scheduling plane: parallel multi-frontend dispatch over a
//! shared worker pool with lock-free shared state.
//!
//! The paper's headline claim is that Rosella "runs in parallel on multiple
//! machines with minimum coordination" (§2): schedulers only ever exchange
//! queue-length probes and periodically synchronized speed estimates. This
//! module realizes that design inside one process:
//!
//! * **N frontend shards** ([`shard`]) each run the complete Rosella loop —
//!   their own Poisson arrival stream (batched, [`ingest`]), their own
//!   policy instance and RNG, and their own arrival estimator — against the
//!   shared pool of live workers ([`crate::coordinator::worker`]);
//! * **shared state** ([`state`]) is lock-free on the decision hot path:
//!   per-worker atomic queue-length probes and a seqlock-published estimate
//!   table that shards re-read only when its epoch moves;
//! * **learning state is owned per scheduler** ([`LearnerMode`]). The §5
//!   design (`LearnerMode::PerShard`) gives every frontend a private
//!   [`PerfLearner`] fed by its *own* completion channel — node monitors
//!   route each report to the scheduler that dispatched the task
//!   ([`crate::coordinator::worker::CompletionSink`]) — plus its own
//!   benchmark dispatcher at the throttled per-scheduler rate
//!   `c0(μ̄ − λ̂_global)/k`, so the aggregate probing budget matches the
//!   single-scheduler design. Schedulers coordinate *only* through
//!   estimate sync, and *when* and *with whom* they sync is pluggable
//!   ([`PlaneConfig::sync_policy`] → [`crate::learner::SyncPolicy`]): a
//!   lightweight thread ([`consensus`]) collects the exported per-shard
//!   [`crate::learner::SyncPayload`]s — per-worker μ̂ views *plus* each
//!   scheduler's local arrival share λ̂ₛ — and publishes consensus through
//!   the seqlock table on a fixed timer (`periodic`, all-to-all), only
//!   when a shard's local estimates diverged beyond a relative-error
//!   threshold from its last adopted consensus (`adaptive`, with a
//!   staleness deadline forcing a merge), or as deterministic pairwise
//!   merges (`gossip`). λ̂_global is the *sum of exchanged shares*, so the
//!   throttle stays correct under skewed arrival routing.
//!   `LearnerMode::Shared` keeps the pre-§5 baseline for comparison: one
//!   aggregator thread owns a single learner fed by a single funnel
//!   channel;
//! * **latency metrics merge at drain**: per-shard [`ResponseRecorder`]s
//!   cover the whole plane without double counting in either mode.
//!
//! Ownership of learning state is the only difference between the modes —
//! the decision hot path (atomic probes + epoch-gated estimate cache) is
//! byte-for-byte the same, so `rosella plane --learners shared` vs
//! `--learners per-shard` compares learning topology, nothing else.
//!
//! `rosella plane` (the CLI stress harness) sweeps the frontend count and
//! reports scheduling decisions/sec and response-time percentiles;
//! `benches/bench_plane.rs` uses the same entry points.
//!
//! ## Cross-process plane
//!
//! The same topology runs across *processes* through the
//! [`crate::net`] subsystem's `Transport` seam
//! ([`crate::net::Transport`]). The seam names the four capabilities a §5
//! frontend needs from its plane — submit a task, refresh queue probes,
//! receive the completions it routed, exchange sync payloads — and the
//! transport-generic frontend loop
//! ([`crate::net::run_frontend_loop`], built on this module's
//! [`FrontendCore`]) runs over either in-process channels
//! ([`crate::net::LocalTransport`]: the same [`WorkerClient`] handles,
//! atomic probes, and seqlock table the native shard threads use) or TCP
//! ([`crate::net::TcpTransport`] speaking the length-prefixed wire
//! protocol to a `rosella plane --listen` pool server). The consensus
//! layer needs no seam at all: remote `SyncExport` frames land in the same
//! [`SharedViews`] slots the in-process shards write, so [`consensus`]'s
//! sync thread — policies, dirty-skip, drain-time full merge — is
//! byte-for-byte shared between the two planes. The native shard loop in
//! [`shard`] keeps its direct atomic path (its decision stream is pinned
//! decision-for-decision against the live coordinator); what crosses the
//! seam is the identical decision core over a probe snapshot instead of
//! live atomics — the coordination price §2 argues is affordable, measured
//! by `benches/bench_net.rs` against the in-process numbers.
//!
//! ## Observability
//!
//! A running plane is observable live, not just through its end-of-run
//! report ([`crate::obs`]):
//!
//! * every shard writes its own [`crate::obs::ShardSlot`] in the always-on
//!   metrics registry — decisions, dispatches, completions, queue-length
//!   and response-time histograms — with relaxed counter bumps only, so
//!   the decision hot path stays O(1) and uncontended (CI gates the
//!   overhead at ≤ 1.10× via `rosella hotpath`). The final registry rides
//!   back on [`PlaneReport::obs`], where its totals must agree with the
//!   report's own conservation counts;
//! * `--metrics-listen ADDR` serves Prometheus text exposition at
//!   `/metrics` — registry surface plus live per-worker queue gauges plus
//!   [`crate::net::wire`] frame counters — shared verbatim with the
//!   `--listen` pool server;
//! * `--flight-record PATH` turns on the decision flight recorder
//!   ([`crate::obs::FlightRecorder`]): a bounded per-shard ring of recent
//!   placements (probed workers and queue lengths seen, chosen worker,
//!   μ̂/λ̂, decision ns) plus consensus merges (policy, consensus shift,
//!   views merged, epoch lag), dumped as JSONL at drain and served live
//!   at `/flight`. Off by default — the hot path then takes zero clock
//!   reads, and nothing here draws RNG or reorders a decision, so the
//!   pinned decision streams stay bit-exact.
//!
//! ## Topology & pinning
//!
//! With the algorithmic overhead gone, what remains on the hot path is the
//! memory system: cache-line ping-pong between cores that share nothing
//! but false sharing, and cross-socket probe traffic. The [`topo`] layer
//! addresses both, opt-in via `--pin {none,cores,sockets}`:
//!
//! * **discovery** parses `/sys/devices/system/cpu/cpu*/topology/` on
//!   Linux ([`CpuTopology::detect`]); any missing or garbage sysfs entry
//!   (containers) degrades to a flat single-package topology over
//!   `available_parallelism` — never an error, never a panic;
//! * **pinning** places shard threads round-robin across packages and
//!   partitions workers per package, then pins each thread with a raw
//!   `sched_setaffinity` syscall (std-only — no libc crate; a no-op
//!   returning `false` off Linux or when the container denies it). Which
//!   CPU each shard landed on is the `rosella_shard_cpu` gauge (−1 =
//!   unpinned), reported in every mode so dashboards keep their series;
//! * **padding** ([`CachePadded`]) gives the per-worker queue probes, the
//!   estimate-table seqlock words, and the consensus view slots a cache
//!   line each. This needs no `unsafe` and cannot change behavior:
//!   `#[repr(align(64))]` is a pure layout attribute — every load, store,
//!   and RMW is the same operation on the same value, only the coherence
//!   traffic moves;
//! * **socket-local probing** (`--pin sockets`, ≥ 2 packages) has each
//!   shard run power-of-two-choices over its same-package worker group,
//!   spilling to the full-view policy only when the local minimum exceeds
//!   [`DEFAULT_SPILL_THRESHOLD`] (counted per shard as
//!   `rosella_cross_socket_decisions_total`).
//!
//! `--pin none` (the default) skips discovery entirely and `cores` never
//! touches a decision input, so both keep the decision stream bit-exact
//! against the pre-pinning plane (pinned by `tests/determinism.rs`);
//! `sockets` intentionally trades that identity for locality.

pub mod consensus;
pub mod ingest;
pub mod shard;
pub mod state;
pub mod topo;

pub use consensus::SharedViews;
pub use ingest::{Arrival, ArrivalBatcher};
pub use shard::{encode_job, job_shard, shard_seeds, FrontendCore, BENCH_LOCAL_JOB};
pub use state::{CachePadded, EstimateCache, EstimateTable, SharedView};
pub use topo::{
    default_poll_shards, pin_current_thread, CpuTopology, PinMode, PlacementPlan,
    DEFAULT_SPILL_THRESHOLD,
};

use crate::coordinator::worker::{
    self, Completion, CompletionSink, LiveTask, PayloadMode, WorkerClient, WorkerHandle,
};
use crate::learner::{
    EstimateView, FakeJobDispatcher, PerfLearner, SyncKind, SyncPolicy, SyncPolicyConfig,
};
use crate::metrics::ResponseRecorder;
use crate::scheduler::PolicyKind;
use crate::stats::{Exponential, Rng};
use crate::types::{TaskKind, WorkerId};
use consensus::lambda_total;
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// What a frontend does with each scheduling decision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DispatchMode {
    /// Dispatch every task to its worker (paced arrivals, full system).
    Execute,
    /// Make decisions at full speed without dispatching — isolates raw
    /// scheduling throughput (probes + sampling + policy) from worker
    /// capacity. Queue probes still read the live worker counters.
    DecideOnly,
}

impl DispatchMode {
    /// CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            DispatchMode::Execute => "execute",
            DispatchMode::DecideOnly => "decide-only",
        }
    }
}

/// Who owns the plane's learning state.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LearnerMode {
    /// One aggregator thread owns a single [`PerfLearner`] fed by a single
    /// completion funnel (the pre-§5 baseline).
    Shared,
    /// Every frontend owns a private [`PerfLearner`] fed by its own
    /// completion channel; consensus via periodic estimate sync (§5).
    PerShard,
}

impl LearnerMode {
    /// CLI name.
    pub fn name(&self) -> &'static str {
        match self {
            LearnerMode::Shared => "shared",
            LearnerMode::PerShard => "per-shard",
        }
    }

    /// Parse the CLI spelling.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "shared" => Ok(LearnerMode::Shared),
            "per-shard" | "pershard" | "per_shard" => Ok(LearnerMode::PerShard),
            other => Err(format!("unknown learner mode '{other}' (shared | per-shard)")),
        }
    }
}

/// Configuration of one plane run.
#[derive(Debug, Clone)]
pub struct PlaneConfig {
    /// Worker speed multipliers (one live worker thread per entry).
    pub speeds: Vec<f64>,
    /// Number of frontend shards.
    pub frontends: usize,
    /// Scheduling policy (instantiated once per shard).
    pub policy: PolicyKind,
    /// Aggregate arrival rate in jobs/sec, split evenly across shards
    /// (Poisson superposition keeps the merged stream Poisson).
    pub rate: f64,
    /// Wall-clock run duration (seconds).
    pub duration: f64,
    /// Mean task demand (unit-speed seconds).
    pub mean_demand: f64,
    /// Ingestion batch size per shard.
    pub batch: usize,
    /// RNG seed (per-shard streams derived via [`shard_seeds`]).
    pub seed: u64,
    /// Estimate publish interval of the aggregator (seconds).
    pub publish_interval: f64,
    /// Jobs arriving before this time are excluded from latency metrics.
    pub warmup: f64,
    /// Dispatch mode.
    pub mode: DispatchMode,
    /// Enable the benchmark-job dispatcher (Execute mode only).
    pub fake_jobs: bool,
    /// Stop each shard after this many decisions (None = run to duration).
    pub max_decisions: Option<u64>,
    /// Record per-shard placement sequences (test instrumentation).
    pub record_placements: bool,
    /// Who owns the learning state (§5: per-shard learners + estimate
    /// sync, or the shared-aggregator baseline).
    pub learners: LearnerMode,
    /// Estimate-sync consensus interval in seconds (per-shard mode only).
    pub sync_interval: f64,
    /// How consensus epochs are scheduled on that interval (per-shard mode
    /// only): periodic all-to-all, divergence-triggered adaptive, or
    /// pairwise gossip.
    pub sync_policy: SyncPolicyConfig,
    /// Serve Prometheus text exposition at this address for the run's
    /// duration (`/metrics`, plus `/flight` when the recorder is on).
    pub metrics_listen: Option<String>,
    /// Dump the decision flight recorder as JSONL to this path at drain.
    /// `None` = recorder off: the decision path takes zero clock reads.
    pub flight_record: Option<String>,
    /// Thread placement: `None` (default, topology untouched), `Cores`
    /// (pin shards and workers, decisions unchanged), or `Sockets`
    /// (pinning plus socket-local probing).
    pub pin: PinMode,
    /// Lifecycle-trace sampling: record one task in `trace_sample`
    /// (deterministic by task-id hash). `0` = tracing off (default): the
    /// decision and completion paths take zero extra clock reads.
    pub trace_sample: u32,
    /// Dump the sampled spans as Chrome trace-event JSON (Perfetto-loadable)
    /// to this path at drain. Requires `trace_sample > 0`.
    pub trace_json: Option<String>,
}

impl Default for PlaneConfig {
    fn default() -> Self {
        Self {
            speeds: vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
            frontends: 4,
            policy: PolicyKind::PPoT {
                tie: crate::scheduler::TieRule::Sq2,
                late_binding: false,
            },
            rate: 400.0,
            duration: 5.0,
            mean_demand: 0.01,
            batch: 64,
            seed: 42,
            publish_interval: 0.2,
            warmup: 0.0,
            mode: DispatchMode::Execute,
            fake_jobs: true,
            max_decisions: None,
            record_placements: false,
            learners: LearnerMode::Shared,
            sync_interval: 0.2,
            sync_policy: SyncPolicyConfig::periodic(),
            metrics_listen: None,
            flight_record: None,
            pin: PinMode::None,
            trace_sample: 0,
            trace_json: None,
        }
    }
}

/// Everything measured during a plane run.
#[derive(Debug)]
pub struct PlaneReport {
    /// Frontend count.
    pub frontends: usize,
    /// Worker count.
    pub workers: usize,
    /// Dispatch mode the run used.
    pub mode: DispatchMode,
    /// Policy name.
    pub policy: String,
    /// Wall-clock seconds until the stop signal.
    pub elapsed: f64,
    /// Total scheduling decisions across shards.
    pub decisions: u64,
    /// Aggregate decisions per second.
    pub decisions_per_sec: f64,
    /// Decisions per shard (scaling diagnostics).
    pub per_shard_decisions: Vec<u64>,
    /// Real tasks dispatched to workers.
    pub dispatched: u64,
    /// Real tasks completed after the full drain.
    pub completed: u64,
    /// Real tasks the aggregator had seen at the stop instant.
    pub completed_at_stop: u64,
    /// Sum of queue-length probes at the stop instant.
    pub queued_at_stop: usize,
    /// Benchmark tasks injected.
    pub benchmarks: u64,
    /// Merged cross-shard response recorder.
    pub responses: ResponseRecorder,
    /// Final speed estimates vs configured speeds.
    pub estimates: Vec<(f64, f64)>,
    /// Per-shard placement sequences (only when recording was enabled).
    pub placements: Vec<Vec<WorkerId>>,
    /// Learner-ownership mode the run used.
    pub learners: LearnerMode,
    /// Estimate-sync check epochs evaluated (per-shard mode; 0 under the
    /// shared aggregator, whose publishes are not consensus).
    pub sync_epochs: u64,
    /// Consensus merge operations performed (all-to-all = 1 each, every
    /// gossip pair = 1; adaptive skips make this smaller than
    /// `sync_epochs`). 0 under the shared aggregator.
    pub sync_merges: u64,
    /// Each shard's final exported learner view (per-shard mode; empty
    /// otherwise). `estimates` is exactly their
    /// [`merge_estimates`](crate::learner::merge_estimates) consensus.
    pub shard_views: Vec<Vec<EstimateView>>,
    /// The run's metrics registry, final state. Counters here are the same
    /// stream the `/metrics` endpoint scraped mid-run, so tests can check
    /// conservation against the report totals.
    pub obs: Arc<crate::obs::Registry>,
}

impl PlaneReport {
    /// Render a human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "plane: {} frontends × {} workers, policy {}, mode {}\n",
            self.frontends,
            self.workers,
            self.policy,
            self.mode.name()
        ));
        out.push_str(&format!(
            "decisions  : {} in {:.2}s — {:.0} decisions/s\n",
            self.decisions, self.elapsed, self.decisions_per_sec
        ));
        out.push_str(&format!(
            "dispatched : {} | completed {} | benchmarks {}\n",
            self.dispatched, self.completed, self.benchmarks
        ));
        out.push_str(&format!(
            "at stop    : completed {} + queued {} ≤ dispatched {}\n",
            self.completed_at_stop, self.queued_at_stop, self.dispatched
        ));
        if self.responses.count() > 0 {
            let five = self.responses.five_num();
            out.push_str(&format!(
                "latency ms : mean {:.1} | p50 {:.1} | p95 {:.1} ({} jobs)\n",
                self.responses.mean() * 1e3,
                five.p50 * 1e3,
                five.p95 * 1e3,
                self.responses.count()
            ));
        }
        match self.learners {
            LearnerMode::Shared => {
                out.push_str("learning   : one shared learner (aggregator thread)\n");
            }
            LearnerMode::PerShard => {
                out.push_str(&format!(
                    "learning   : per-shard learners, {} estimate-sync epochs, {} merges\n",
                    self.sync_epochs, self.sync_merges
                ));
                for (s, views) in self.shard_views.iter().enumerate() {
                    let samples: Vec<u64> = views.iter().map(|v| v.samples).collect();
                    out.push_str(&format!("  shard {s} in-window samples: {samples:?}\n"));
                }
            }
        }
        out.push_str("worker speed estimates (true → learned):\n");
        for (i, (truth, est)) in self.estimates.iter().enumerate() {
            out.push_str(&format!("  worker {i}: {truth:.2} → {est:.2}\n"));
        }
        out
    }
}

/// State moved into the aggregator thread.
struct AggCtx {
    comp_rx: Receiver<Completion>,
    table: Arc<EstimateTable>,
    stop: Arc<AtomicBool>,
    completed_real: Arc<AtomicU64>,
    lambda_slots: Vec<Arc<AtomicU64>>,
    bench_pool: Option<Vec<WorkerClient>>,
    shards: usize,
    n: usize,
    prior: f64,
    mu_bar: f64,
    mean_demand: f64,
    warmup: f64,
    publish_interval: f64,
    seed: u64,
    start: Instant,
    obs: Arc<crate::obs::Registry>,
    tracer: Option<Arc<crate::obs::Tracer>>,
}

/// What the aggregator hands back at drain.
struct AggOut {
    responses: Vec<ResponseRecorder>,
    mu_hat: Vec<f64>,
    benchmarks: u64,
}

/// One catch-up pass of the LEARNER-DISPATCHER loop (Fig. 6), generic over
/// how a benchmark task reaches its worker — in-process pool enqueue or the
/// net plane's transport submit — so the throttle loop (gap clamp, uniform
/// worker draw, demand floor) exists exactly once. `lambda` is sampled once
/// per pass — within one catch-up burst the estimate cannot meaningfully
/// move. Returns how many tasks were sent.
pub(crate) fn dispatch_benchmarks_with<E>(
    dispatcher: &FakeJobDispatcher,
    workers: usize,
    lambda: f64,
    demand_dist: &Exponential,
    rng: &mut Rng,
    next_bench: &mut Instant,
    mut submit: E,
) -> Result<u64, String>
where
    E: FnMut(usize, f64) -> Result<(), String>,
{
    if !dispatcher.enabled() {
        return Ok(0);
    }
    let mut sent = 0;
    while Instant::now() >= *next_bench {
        let gap = dispatcher.next_gap(lambda, rng).unwrap_or(1.0).clamp(1e-3, 1.0);
        let w = dispatcher.pick_worker(workers, rng);
        submit(w, demand_dist.sample(rng).max(1e-4))?;
        sent += 1;
        *next_bench += Duration::from_secs_f64(gap);
    }
    Ok(sent)
}

/// [`dispatch_benchmarks_with`] over the in-process worker pool — the pass
/// shared by the shared-mode aggregator and every per-shard learner.
pub(crate) fn dispatch_benchmarks(
    dispatcher: &FakeJobDispatcher,
    pool: &[WorkerClient],
    lambda: f64,
    job: u64,
    demand_dist: &Exponential,
    rng: &mut Rng,
    next_bench: &mut Instant,
) -> u64 {
    dispatch_benchmarks_with(
        dispatcher,
        pool.len(),
        lambda,
        demand_dist,
        rng,
        next_bench,
        |w, demand| {
            pool[w].enqueue(LiveTask {
                job,
                kind: TaskKind::Benchmark,
                demand,
                enqueued: Instant::now(),
            });
            Ok(())
        },
    )
    .expect("in-process enqueue is infallible")
}

fn record_completion(
    perf: &mut PerfLearner,
    responses: &mut [ResponseRecorder],
    ctx: &AggCtx,
    c: &Completion,
) {
    let now_s = (c.at - ctx.start).as_secs_f64();
    perf.on_completion(c.worker, now_s, c.duration.max(1e-6), c.demand);
    if c.kind == TaskKind::Real {
        let s = job_shard(c.job);
        if s < responses.len() {
            responses[s].record((now_s - c.sojourn).max(0.0), now_s);
            let slot = ctx.obs.shard(s);
            slot.completed.inc();
            slot.response_us.record(((now_s - c.sojourn).max(0.0) * 1e6) as u64);
        }
        if let Some(tr) = ctx.tracer.as_ref() {
            tr.record_completion(c.job, c.queue_wait(), c.duration, c.at);
        }
        // Release pairs with the Acquire load in `run_plane`'s stop
        // snapshot: a task counted here already left its queue probe.
        ctx.completed_real.fetch_add(1, Ordering::Release);
    }
}

/// The aggregator thread body: the plane's single learner writer.
fn aggregate(mut ctx: AggCtx) -> AggOut {
    let mut responses: Vec<ResponseRecorder> =
        (0..ctx.shards).map(|_| ResponseRecorder::new(ctx.warmup)).collect();
    let mut perf = PerfLearner::new(ctx.n, 10.0, ctx.mean_demand, ctx.mu_bar, ctx.prior, 0.0);
    let dispatcher = FakeJobDispatcher::new(0.1, ctx.mu_bar, ctx.bench_pool.is_some());
    let demand_dist = Exponential::with_mean(ctx.mean_demand);
    let mut rng = Rng::new(ctx.seed ^ 0xA66_A66);
    let mut benchmarks = 0u64;
    let mut next_publish = ctx.start + Duration::from_secs_f64(ctx.publish_interval);
    let mut next_bench = ctx.start + Duration::from_secs_f64(0.05);

    loop {
        match ctx.comp_rx.recv_timeout(Duration::from_millis(2)) {
            Ok(c) => {
                record_completion(&mut perf, &mut responses, &ctx, &c);
                while let Ok(c) = ctx.comp_rx.try_recv() {
                    record_completion(&mut perf, &mut responses, &ctx, &c);
                }
            }
            Err(RecvTimeoutError::Timeout) => {}
            // All workers exited and their queues drained: we are done.
            Err(RecvTimeoutError::Disconnected) => break,
        }
        if ctx.stop.load(Ordering::Relaxed) {
            // Release our senders so the workers can finish draining.
            ctx.bench_pool = None;
        }
        // The same LEARNER-DISPATCHER pass the per-shard learners run —
        // here at the aggregate rate with the plane-wide λ̂ (the live
        // coordinator's serve loop remains its own copy).
        if let Some(pool) = ctx.bench_pool.as_ref() {
            let sent = dispatch_benchmarks(
                &dispatcher,
                pool,
                lambda_total(&ctx.lambda_slots),
                u64::MAX,
                &demand_dist,
                &mut rng,
                &mut next_bench,
            );
            if sent > 0 {
                // Shared mode has no dispatching shard for benchmark
                // probes: attribute the aggregator's injections to slot 0.
                ctx.obs.shard(0).bench_dispatched.add(sent);
            }
            benchmarks += sent;
        }
        if Instant::now() >= next_publish {
            let now_s = ctx.start.elapsed().as_secs_f64();
            let lam = lambda_total(&ctx.lambda_slots);
            perf.publish(now_s, lam);
            ctx.table.publish(perf.mu_hat(), lam);
            ctx.obs.set_mu_hat(perf.mu_hat());
            ctx.obs.lambda_hat.set(lam);
            ctx.obs.publishes.inc();
            next_publish += Duration::from_secs_f64(ctx.publish_interval);
        }
    }
    // Final publish so reports reflect the learner's last word.
    let lam = lambda_total(&ctx.lambda_slots);
    perf.publish(ctx.start.elapsed().as_secs_f64(), lam);
    ctx.table.publish(perf.mu_hat(), lam);
    ctx.obs.set_mu_hat(perf.mu_hat());
    ctx.obs.lambda_hat.set(lam);
    ctx.obs.publishes.inc();
    AggOut { responses, mu_hat: perf.mu_hat().to_vec(), benchmarks }
}

/// Run the sharded scheduling plane to completion.
pub fn run_plane(cfg: PlaneConfig) -> Result<PlaneReport, String> {
    let n = cfg.speeds.len();
    if n == 0 {
        return Err("need at least one worker".into());
    }
    if cfg.frontends == 0 {
        return Err("need at least one frontend".into());
    }
    if !(cfg.rate > 0.0 && cfg.duration > 0.0 && cfg.mean_demand > 0.0 && cfg.batch >= 1) {
        return Err("rate, duration, mean demand, and batch must be positive".into());
    }
    let per_shard = cfg.learners == LearnerMode::PerShard;
    if per_shard {
        if !(cfg.sync_interval > 0.0 && cfg.sync_interval.is_finite()) {
            return Err("per-shard learners need a positive finite sync interval".into());
        }
        cfg.sync_policy
            .validate(cfg.sync_interval)
            .map_err(|e| format!("sync policy: {e}"))?;
    } else {
        if cfg.sync_policy.kind != SyncKind::Periodic {
            return Err(format!(
                "--sync-policy {} needs --learners per-shard (the shared aggregator has no \
                 consensus to schedule)",
                cfg.sync_policy.kind.name()
            ));
        }
        // The threshold field is validated even where it is unused (shared
        // mode): a NaN or negative --sync-threshold is a config mistake to
        // reject loudly, not dead data to carry into reports.
        cfg.sync_policy
            .validate(cfg.sync_interval)
            .map_err(|e| format!("sync policy: {e}"))?;
    }
    let k = cfg.frontends;
    let total_speed: f64 = cfg.speeds.iter().sum();
    let prior = total_speed / n as f64;
    let mu_bar = total_speed / cfg.mean_demand;
    let policy_name = cfg.policy.build(n).name();

    // Thread placement, computed once before any thread spawns. `--pin
    // none` skips topology discovery entirely — the pre-pinning plane,
    // byte-for-byte.
    let plan = match cfg.pin {
        PinMode::None => PlacementPlan::unpinned(k, n),
        mode => PlacementPlan::new(mode, &CpuTopology::detect(), k, n),
    };

    // Completion plumbing: the shared aggregator owns one funnel channel;
    // per-shard learners get one channel each, and every node monitor
    // routes each report to the scheduler that dispatched the task.
    let mut agg_rx: Option<Receiver<Completion>> = None;
    let mut shard_rxs: Vec<Receiver<Completion>> = Vec::new();
    let sink = if per_shard {
        let mut txs = Vec::with_capacity(k);
        for _ in 0..k {
            let (tx, rx) = std::sync::mpsc::channel::<Completion>();
            txs.push(tx);
            shard_rxs.push(rx);
        }
        CompletionSink::sharded(txs)
    } else {
        let (tx, rx) = std::sync::mpsc::channel::<Completion>();
        agg_rx = Some(rx);
        CompletionSink::from(tx)
    };

    // The shared worker pool (workers pinned per the placement plan).
    let workers: Vec<WorkerHandle> = cfg
        .speeds
        .iter()
        .enumerate()
        .map(|(i, &s)| {
            worker::spawn_pinned(i, s, PayloadMode::Sleep, sink.clone(), plan.worker_cpus[i])
        })
        .collect();
    drop(sink);
    let qlen: Vec<Arc<CachePadded<AtomicUsize>>> =
        workers.iter().map(|w| w.client.qlen.clone()).collect();

    // Lock-free shared state.
    let table = Arc::new(EstimateTable::new(n, prior));
    let stop = Arc::new(AtomicBool::new(false));
    // Shards bump this when they leave the decision loop; per-shard drains
    // block on worker exit, so thread-finished is not "done deciding".
    let done_deciding = Arc::new(AtomicUsize::new(0));
    let completed_real = Arc::new(AtomicU64::new(0));
    let lambda_slots: Vec<Arc<AtomicU64>> =
        (0..k).map(|_| Arc::new(AtomicU64::new(0f64.to_bits()))).collect();
    let start = Instant::now();

    // Observability: the metrics registry is always on (per-shard slots,
    // counter bumps only on the hot path); the flight recorder and the
    // scrape endpoint are opt-in.
    let obs = Arc::new(crate::obs::Registry::new(k, n));
    let flight = cfg.flight_record.as_deref().map(|_| {
        Arc::new(crate::obs::FlightRecorder::new(k, crate::obs::flight::DEFAULT_CAPACITY))
    });
    let tracer =
        (cfg.trace_sample > 0).then(|| Arc::new(crate::obs::Tracer::new(cfg.trace_sample)));
    let metrics = match cfg.metrics_listen.as_deref() {
        Some(addr) => Some(spawn_metrics_server(
            addr,
            obs.clone(),
            flight.clone(),
            qlen.clone(),
            tracer.clone(),
        )?),
        None => None,
    };

    // Estimate-sync consensus (per-shard mode): view slots + the sync
    // thread, the table's only writer in this mode. It gets its own stop
    // flag so the final consensus epoch runs after every shard has
    // exported its drain-time view.
    let views = per_shard.then(|| Arc::new(SharedViews::new(k, n, prior)));
    let sync_stop = Arc::new(AtomicBool::new(false));
    let sync_handle = match views.as_ref() {
        Some(v) => {
            let ctx = consensus::SyncRun {
                views: v.clone(),
                table: table.clone(),
                stop: sync_stop.clone(),
                policy: SyncPolicy::new(
                    &cfg.sync_policy,
                    cfg.sync_interval,
                    k,
                    cfg.seed ^ 0x57AC_6E55,
                ),
                prior,
                start,
                obs: obs.clone(),
                flight: flight.clone(),
            };
            Some(
                std::thread::Builder::new()
                    .name("rosella-plane-sync".into())
                    .spawn(move || consensus::run_sync(ctx))
                    .map_err(|e| format!("spawn sync thread: {e}"))?,
            )
        }
        None => None,
    };

    // The aggregator (shared mode only: the single learner writer).
    let agg = match agg_rx {
        Some(comp_rx) => {
            let ctx = AggCtx {
                comp_rx,
                table: table.clone(),
                stop: stop.clone(),
                completed_real: completed_real.clone(),
                lambda_slots: lambda_slots.clone(),
                bench_pool: (cfg.mode == DispatchMode::Execute && cfg.fake_jobs)
                    .then(|| workers.iter().map(|w| w.client.clone()).collect()),
                shards: k,
                n,
                prior,
                mu_bar,
                mean_demand: cfg.mean_demand,
                warmup: cfg.warmup,
                publish_interval: cfg.publish_interval,
                seed: cfg.seed,
                start,
                obs: obs.clone(),
                tracer: tracer.clone(),
            };
            Some(
                std::thread::Builder::new()
                    .name("rosella-plane-agg".into())
                    .spawn(move || aggregate(ctx))
                    .map_err(|e| format!("spawn aggregator: {e}"))?,
            )
        }
        None => None,
    };

    // The frontend shards.
    let mut shard_handles = Vec::with_capacity(k);
    let mut shard_rx_iter = shard_rxs.into_iter();
    for i in 0..k {
        let ctx = shard::ShardRun {
            id: i,
            policy: cfg.policy.clone(),
            n,
            prior,
            mean_demand: cfg.mean_demand,
            rate: cfg.rate / k as f64,
            batch: cfg.batch,
            seed: cfg.seed,
            mode: cfg.mode,
            max_decisions: cfg.max_decisions,
            record_placements: cfg.record_placements,
            workers: workers.iter().map(|w| w.client.clone()).collect(),
            qlen: qlen.clone(),
            table: table.clone(),
            cpu: plan.shard_cpus[i],
            group: plan.shard_groups[i].clone(),
            spill_threshold: DEFAULT_SPILL_THRESHOLD,
            lambda_slot: lambda_slots[i].clone(),
            stop: stop.clone(),
            done_deciding: done_deciding.clone(),
            start,
            mu_bar,
            publish_interval: cfg.publish_interval,
            warmup: cfg.warmup,
            fake_jobs: cfg.fake_jobs,
            shards: k,
            divergence_threshold: (per_shard && cfg.sync_policy.kind == SyncKind::Adaptive)
                .then(|| cfg.sync_policy.scaled_threshold(k)),
            obs: obs.clone(),
            flight: flight.clone(),
            tracer: tracer.clone(),
            learner: shard_rx_iter.next().map(|comp_rx| shard::ShardLearner {
                comp_rx,
                views: views.as_ref().expect("per-shard views exist").clone(),
                lambda_slots: lambda_slots.clone(),
                completed_real: completed_real.clone(),
            }),
        };
        shard_handles.push(
            std::thread::Builder::new()
                .name(format!("rosella-shard-{i}"))
                .spawn(move || shard::run_shard(ctx))
                .map_err(|e| format!("spawn shard {i}: {e}"))?,
        );
    }

    // Serve until the deadline (or until budgeted shards finish early —
    // "finished" meaning done deciding: a per-shard drain keeps the thread
    // alive until the pool shuts down below).
    let deadline = start + Duration::from_secs_f64(cfg.duration);
    while Instant::now() < deadline && done_deciding.load(Ordering::Relaxed) < k {
        std::thread::sleep(Duration::from_millis(2));
    }
    stop.store(true, Ordering::Relaxed);

    // Stop-instant conservation snapshot. Completions are read *before*
    // the queue probes: a completion increment happens after its
    // queue-length decrement, so completed_at_stop + queued_at_stop never
    // exceeds dispatched (the remainder is tasks mid-handoff). In
    // per-shard mode the snapshot must precede the pool shutdown below;
    // late dispatches between the stop flag and a shard noticing it only
    // grow the final `dispatched`, preserving the inequality.
    let completed_at_stop = completed_real.load(Ordering::Acquire);
    let queued_at_stop: usize = qlen.iter().map(|q| q.load(Ordering::Relaxed)).sum();
    let elapsed = start.elapsed().as_secs_f64();

    let mut workers = Some(workers);
    if per_shard {
        // The shards finish by draining their own completion channels,
        // which disconnect only once the workers exit — so the pool must
        // shut down before the shards are joined (each shard dropped its
        // ingress clients when it saw the stop flag).
        for w in workers.take().expect("pool not yet shut down") {
            w.shutdown();
        }
    }

    let mut decisions = 0u64;
    let mut dispatched = 0u64;
    let mut benchmarks = 0u64;
    let mut per_shard_decisions = Vec::with_capacity(k);
    let mut placements = Vec::with_capacity(k);
    let mut shard_views = Vec::with_capacity(if per_shard { k } else { 0 });
    let mut responses = ResponseRecorder::new(cfg.warmup);
    for h in shard_handles {
        let s = h.join().map_err(|_| "shard thread panicked".to_string())?;
        decisions += s.decisions;
        dispatched += s.dispatched;
        benchmarks += s.benchmarks;
        per_shard_decisions.push(s.decisions);
        placements.push(s.placements);
        if per_shard {
            responses.merge(&s.responses);
            shard_views.push(s.views);
        }
    }

    let (estimates, sync_epochs, sync_merges) = if per_shard {
        // Final consensus epoch over the drain-time views (always a full
        // merge, whatever the policy), then read the table: the reported
        // estimates *are* the published consensus.
        sync_stop.store(true, Ordering::Release);
        let outcome = sync_handle
            .expect("per-shard sync thread exists")
            .join()
            .map_err(|_| "sync thread panicked".to_string())?;
        let (mu, _lambda) = table.snapshot();
        let estimates: Vec<(f64, f64)> =
            cfg.speeds.iter().zip(mu.iter()).map(|(&t, &e)| (t, e)).collect();
        (estimates, outcome.epochs, outcome.merges)
    } else {
        // Shut the pool down: every sender drops, workers drain their
        // queues and exit, the aggregator sees the disconnect and returns.
        for w in workers.take().expect("pool not yet shut down") {
            w.shutdown();
        }
        let out = agg
            .expect("shared-mode aggregator exists")
            .join()
            .map_err(|_| "aggregator thread panicked".to_string())?;
        for r in &out.responses {
            responses.merge(r);
        }
        benchmarks = out.benchmarks;
        let estimates: Vec<(f64, f64)> =
            cfg.speeds.iter().zip(out.mu_hat.iter()).map(|(&t, &e)| (t, e)).collect();
        (estimates, 0, 0)
    };
    let completed = completed_real.load(Ordering::Acquire);

    // Scrape endpoint down first (its handler holds registry/qlen clones),
    // then the flight dump: drain-time JSONL covers the whole run.
    if let Some(srv) = metrics {
        srv.shutdown();
    }
    if let (Some(rec), Some(path)) = (flight.as_ref(), cfg.flight_record.as_ref()) {
        std::fs::write(path, rec.dump_jsonl())
            .map_err(|e| format!("write flight record {path}: {e}"))?;
    }
    if let (Some(tr), Some(path)) = (tracer.as_ref(), cfg.trace_json.as_ref()) {
        tr.dump_chrome_json(path).map_err(|e| format!("write trace json {path}: {e}"))?;
    }

    Ok(PlaneReport {
        frontends: k,
        workers: n,
        mode: cfg.mode,
        policy: policy_name,
        elapsed,
        decisions,
        decisions_per_sec: decisions as f64 / elapsed,
        per_shard_decisions,
        dispatched,
        completed,
        completed_at_stop,
        queued_at_stop,
        benchmarks,
        responses,
        estimates,
        placements,
        learners: cfg.learners,
        sync_epochs,
        sync_merges,
        shard_views,
        obs,
    })
}

/// Start the scrape endpoint over a live registry: `/metrics` serves the
/// standard exposition plus live per-worker queue gauges and the
/// process-wide wire-frame counters; `/flight` serves the recorder's
/// JSONL when a recorder is on (404 otherwise); `/trace` serves the
/// sampled lifecycle spans as Chrome trace-event JSON when tracing is on
/// (404 otherwise). Shared by the in-process plane and the `--listen`
/// pool server so both modes expose the same surface.
pub(crate) fn spawn_metrics_server(
    addr: &str,
    obs: Arc<crate::obs::Registry>,
    flight: Option<Arc<crate::obs::FlightRecorder>>,
    qlen: Vec<Arc<CachePadded<AtomicUsize>>>,
    tracer: Option<Arc<crate::obs::Tracer>>,
) -> Result<crate::obs::MetricsServer, String> {
    let handler: Arc<crate::obs::scrape::Handler> = Arc::new(move |path: &str| match path {
        "/metrics" => {
            let mut e = crate::obs::Expo::new();
            crate::obs::expo::render_into(&obs, &mut e);
            e.header("rosella_worker_queue_len", "gauge");
            for (w, q) in qlen.iter().enumerate() {
                let label = w.to_string();
                e.sample(
                    "rosella_worker_queue_len",
                    &[("worker", &label)],
                    q.load(Ordering::Relaxed) as f64,
                );
            }
            let wire = crate::net::wire::frame_totals();
            e.counter("rosella_wire_frames_sent_total", &[(&[], wire.frames_sent)]);
            e.counter("rosella_wire_frames_received_total", &[(&[], wire.frames_received)]);
            e.counter("rosella_wire_bytes_sent_total", &[(&[], wire.bytes_sent)]);
            e.counter("rosella_wire_bytes_received_total", &[(&[], wire.bytes_received)]);
            if let Some(rec) = flight.as_ref() {
                e.counter("rosella_flight_dropped_total", &[(&[], rec.dropped())]);
            }
            let mut body = e.finish();
            if let Some(tr) = tracer.as_ref() {
                tr.render_prometheus(&mut body);
            }
            Some((crate::obs::scrape::EXPOSITION_CONTENT_TYPE, body))
        }
        "/flight" => {
            flight.as_ref().map(|rec| ("application/x-ndjson", rec.dump_jsonl()))
        }
        "/trace" => {
            tracer.as_ref().map(|tr| ("application/json", tr.render_chrome_json()))
        }
        _ => None,
    });
    crate::obs::MetricsServer::spawn(addr, handler)
        .map_err(|e| format!("metrics listener {addr}: {e}"))
}

/// Run the plane once per frontend count in `sweep` with otherwise
/// identical configuration — the throughput-scaling harness.
pub fn sweep(base: &PlaneConfig, frontend_counts: &[usize]) -> Result<Vec<PlaneReport>, String> {
    let mut reports = Vec::with_capacity(frontend_counts.len());
    for &k in frontend_counts {
        let cfg = PlaneConfig { frontends: k, ..base.clone() };
        reports.push(run_plane(cfg)?);
    }
    Ok(reports)
}

/// Machine-readable sweep results (`BENCH_plane.json`) so future changes
/// can track the throughput trajectory.
pub fn bench_json(base: &PlaneConfig, reports: &[PlaneReport]) -> crate::config::Json {
    use crate::config::Json;
    use std::collections::BTreeMap;
    let results: Vec<Json> = reports
        .iter()
        .map(|r| {
            let mut m = BTreeMap::new();
            m.insert("frontends".into(), Json::Num(r.frontends as f64));
            m.insert("decisions".into(), Json::Num(r.decisions as f64));
            m.insert("decisions_per_sec".into(), Json::Num(r.decisions_per_sec.round()));
            m.insert("dispatched".into(), Json::Num(r.dispatched as f64));
            m.insert("completed".into(), Json::Num(r.completed as f64));
            let five = r.responses.five_num();
            m.insert("mean_ms".into(), Json::Num(r.responses.mean() * 1e3));
            m.insert("p50_ms".into(), Json::Num(five.p50 * 1e3));
            m.insert("p95_ms".into(), Json::Num(five.p95 * 1e3));
            m.insert("sync_epochs".into(), Json::Num(r.sync_epochs as f64));
            m.insert("sync_merges".into(), Json::Num(r.sync_merges as f64));
            Json::Obj(m)
        })
        .collect();
    let mut top = BTreeMap::new();
    top.insert("bench".into(), Json::Str("plane".into()));
    top.insert("mode".into(), Json::Str(base.mode.name().into()));
    top.insert("learners".into(), Json::Str(base.learners.name().into()));
    top.insert("sync_interval".into(), Json::Num(base.sync_interval));
    top.insert("sync_policy".into(), Json::Str(base.sync_policy.kind.name().into()));
    top.insert("sync_threshold".into(), Json::Num(base.sync_policy.threshold));
    top.insert("policy".into(), Json::Str(base.policy.build(base.speeds.len()).name()));
    top.insert("workers".into(), Json::Num(base.speeds.len() as f64));
    top.insert("rate".into(), Json::Num(base.rate));
    top.insert("duration".into(), Json::Num(base.duration));
    top.insert("seed".into(), Json::Num(base.seed as f64));
    let detected = CpuTopology::detect();
    let mut t = BTreeMap::new();
    t.insert("cpus".into(), Json::Num(detected.n_cpus() as f64));
    t.insert("packages".into(), Json::Num(detected.n_packages() as f64));
    t.insert("pin".into(), Json::Str(base.pin.name().into()));
    top.insert("topology".into(), Json::Obj(t));
    top.insert("results".into(), Json::Arr(results));
    Json::Obj(top)
}

/// Resolve `--workers`/`--speeds` into a concrete speed vector — shared by
/// the in-process sweep CLI and the net pool server (`plane --listen`), so
/// the two `plane` modes cannot drift apart on the default mix.
pub(crate) fn speeds_from_cli(p: &crate::cli::Parsed) -> Result<Vec<f64>, String> {
    let workers: usize = p.parse_as("workers")?.unwrap_or(8);
    Ok(match p.get("speeds") {
        Some(s) => crate::cluster::SpeedProfile::parse(s)?.speeds(&mut Rng::new(1)),
        None => {
            let base = [2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25];
            (0..workers).map(|i| base[i % base.len()]).collect()
        }
    })
}

/// CLI adapter for `rosella plane`.
pub fn plane_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let speeds = speeds_from_cli(p)?;
    let frontend_counts: Vec<usize> = p
        .get("frontends")
        .unwrap_or("1,2,4")
        .split(',')
        .map(|t| t.trim().parse::<usize>().map_err(|e| format!("bad frontend count: {e}")))
        .collect::<Result<_, _>>()?;
    if frontend_counts.is_empty() {
        return Err("need at least one frontend count".into());
    }
    let base = PlaneConfig {
        speeds,
        policy: PolicyKind::parse(p.get("policy").unwrap_or("ppot"))?,
        rate: p.parse_as("rate")?.unwrap_or(400.0),
        duration: p.parse_as("duration")?.unwrap_or(3.0),
        mean_demand: p.parse_as("demand")?.unwrap_or(0.01),
        batch: p.parse_as("batch")?.unwrap_or(64),
        seed: p.parse_as("seed")?.unwrap_or(42),
        mode: if p.flag("decide-only") { DispatchMode::DecideOnly } else { DispatchMode::Execute },
        fake_jobs: !p.flag("no-fake-jobs"),
        learners: LearnerMode::parse(p.get("learners").unwrap_or("shared"))?,
        sync_interval: p.parse_as("sync-interval")?.unwrap_or(0.2),
        sync_policy: {
            let mut sp = SyncPolicyConfig {
                kind: SyncKind::parse(p.get("sync-policy").unwrap_or("periodic"))?,
                ..SyncPolicyConfig::default()
            };
            if let Some(t) = p.parse_as("sync-threshold")? {
                sp.threshold = t;
            }
            sp
        },
        metrics_listen: p.get("metrics-listen").map(str::to_string),
        flight_record: p.get("flight-record").map(str::to_string),
        pin: PinMode::parse(p.get("pin").unwrap_or("none"))?,
        trace_sample: match p.get("trace-sample") {
            Some(spec) => crate::obs::trace::parse_sample(spec)?,
            None => 0,
        },
        trace_json: p.get("trace-json").map(str::to_string),
        ..PlaneConfig::default()
    };
    let reports = sweep(&base, &frontend_counts)?;

    let mut out = String::new();
    for r in &reports {
        out.push_str(&r.render());
        out.push('\n');
    }
    out.push_str("frontends   decisions/s   speedup   p50 ms   p95 ms\n");
    let base_rate = reports[0].decisions_per_sec.max(1.0);
    for r in &reports {
        let five = r.responses.five_num();
        out.push_str(&format!(
            "{:>9}   {:>11.0}   {:>7.2}   {:>6.1}   {:>6.1}\n",
            r.frontends,
            r.decisions_per_sec,
            r.decisions_per_sec / base_rate,
            five.p50 * 1e3,
            five.p95 * 1e3
        ));
    }
    if let Some(path) = p.get("json") {
        let doc = crate::config::to_string(&bench_json(&base, &reports));
        std::fs::write(path, doc).map_err(|e| format!("write {path}: {e}"))?;
        out.push_str(&format!("wrote {path}\n"));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::types::JobSpec;

    fn quick(frontends: usize, mode: DispatchMode) -> PlaneConfig {
        PlaneConfig {
            speeds: vec![1.0, 0.5, 0.25, 2.0],
            frontends,
            rate: 400.0,
            duration: 1.2,
            mean_demand: 0.003,
            publish_interval: 0.1,
            mode,
            ..PlaneConfig::default()
        }
    }

    #[test]
    fn single_shard_plane_matches_live_coordinator_decision_sequence() {
        // A one-shard plane with idle workers must reproduce, decision for
        // decision, what the live coordinator's FrontendCore produces for
        // the same seed — the placement stream is a pure function of the
        // seed schedule shared by both paths.
        let cfg = PlaneConfig {
            frontends: 1,
            mode: DispatchMode::DecideOnly,
            max_decisions: Some(400),
            record_placements: true,
            fake_jobs: false,
            duration: 30.0,
            ..quick(1, DispatchMode::DecideOnly)
        };
        let report = run_plane(cfg.clone()).unwrap();
        assert_eq!(report.decisions, 400);
        assert_eq!(report.placements[0].len(), 400);
        assert_eq!(report.dispatched, 0, "decide-only must not dispatch");

        // Replay the live coordinator's decision path: same seed schedule,
        // same arrival stream, zero queue probes.
        let n = cfg.speeds.len();
        let prior = cfg.speeds.iter().sum::<f64>() / n as f64;
        let (core_seed, stream_seed) = shard_seeds(cfg.seed, 0);
        let mut core =
            FrontendCore::new(&cfg.policy, n, prior, cfg.mean_demand, 128, core_seed);
        let mut rng = Rng::new(stream_seed);
        let mut batcher = ArrivalBatcher::new(cfg.rate, cfg.mean_demand, cfg.batch);
        let mut batch = Vec::new();
        let zeros = vec![0usize; n];
        let mut job = JobSpec::single(cfg.mean_demand);
        let mut expected = Vec::with_capacity(400);
        'outer: loop {
            batcher.fill(&mut rng, &mut batch);
            for a in &batch {
                if expected.len() == 400 {
                    break 'outer;
                }
                core.on_arrival(a.at, 1);
                job.tasks[0].demand = a.demand;
                expected.push(core.decide_local(&job, &zeros));
            }
        }
        assert_eq!(report.placements[0], expected, "plane diverged from coordinator core");
    }

    #[test]
    fn four_shard_run_conserves_tasks() {
        let report = run_plane(quick(4, DispatchMode::Execute)).unwrap();
        assert!(report.dispatched > 100, "dispatched {}", report.dispatched);
        // After the full drain every dispatched task completed exactly once.
        assert_eq!(
            report.completed, report.dispatched,
            "tasks lost or duplicated across the drain"
        );
        // The stop-instant snapshot can only under-count mid-handoff tasks.
        assert!(
            report.completed_at_stop + report.queued_at_stop as u64 <= report.dispatched,
            "at-stop accounting over-counts: {} + {} > {}",
            report.completed_at_stop,
            report.queued_at_stop,
            report.dispatched
        );
        // All four shards actually scheduled work.
        assert_eq!(report.per_shard_decisions.len(), 4);
        assert!(report.per_shard_decisions.iter().all(|&d| d > 0), "idle shard");
        // Cross-shard latency merge saw every completed job.
        assert_eq!(report.responses.count() as u64, report.completed);
    }

    #[test]
    fn pinned_sockets_plane_conserves_tasks() {
        // Sockets mode flips on best-effort pinning and (on multi-package
        // hosts) socket-local probing with cross-socket spill. Whatever the
        // host looks like — single package, pinning denied by the container,
        // or a real two-socket box — conservation must hold unchanged.
        let cfg = PlaneConfig { pin: PinMode::Sockets, ..quick(2, DispatchMode::Execute) };
        let report = run_plane(cfg).unwrap();
        assert!(report.dispatched > 100, "dispatched {}", report.dispatched);
        assert_eq!(
            report.completed, report.dispatched,
            "tasks lost or duplicated under socket pinning"
        );
        assert_eq!(report.per_shard_decisions.len(), 2);
        assert!(report.per_shard_decisions.iter().all(|&d| d > 0), "idle shard");
    }

    #[test]
    fn plane_learns_speed_ordering_across_shards() {
        let cfg = PlaneConfig {
            speeds: vec![2.0, 0.4],
            frontends: 2,
            rate: 300.0,
            duration: 2.0,
            mean_demand: 0.004,
            publish_interval: 0.1,
            ..PlaneConfig::default()
        };
        let report = run_plane(cfg).unwrap();
        assert!(report.completed > 100, "completed {}", report.completed);
        let (t0, e0) = report.estimates[0];
        let (t1, e1) = report.estimates[1];
        assert!(
            e0 > e1,
            "shared learner failed to order speeds: {e0} vs {e1} (true {t0} vs {t1})"
        );
        assert!(report.benchmarks > 0, "benchmark dispatcher idle");
    }

    #[test]
    fn decision_budget_stops_every_shard() {
        let cfg = PlaneConfig {
            frontends: 2,
            mode: DispatchMode::DecideOnly,
            max_decisions: Some(1_000),
            fake_jobs: false,
            duration: 30.0,
            ..quick(2, DispatchMode::DecideOnly)
        };
        let report = run_plane(cfg).unwrap();
        assert_eq!(report.decisions, 2_000);
        assert_eq!(report.per_shard_decisions, vec![1_000, 1_000]);
        assert_eq!(report.dispatched, 0);
    }

    #[test]
    fn invalid_configs_rejected() {
        assert!(run_plane(PlaneConfig { speeds: vec![], ..quick(1, DispatchMode::Execute) })
            .is_err());
        assert!(run_plane(PlaneConfig { frontends: 0, ..quick(1, DispatchMode::Execute) })
            .is_err());
        assert!(run_plane(PlaneConfig { rate: 0.0, ..quick(1, DispatchMode::Execute) }).is_err());
        assert!(run_plane(PlaneConfig {
            learners: LearnerMode::PerShard,
            sync_interval: 0.0,
            ..quick(1, DispatchMode::Execute)
        })
        .is_err());
        // "--sync-interval inf" parses as a float; reject it before the
        // sync thread would panic converting it to a Duration.
        assert!(run_plane(PlaneConfig {
            learners: LearnerMode::PerShard,
            sync_interval: f64::INFINITY,
            ..quick(1, DispatchMode::Execute)
        })
        .is_err());
        // Non-periodic sync policies need a consensus thread to schedule.
        assert!(run_plane(PlaneConfig {
            learners: LearnerMode::Shared,
            sync_policy: SyncPolicyConfig::gossip(),
            ..quick(1, DispatchMode::Execute)
        })
        .is_err());
        // Adaptive knobs are validated before any thread spawns.
        assert!(run_plane(PlaneConfig {
            learners: LearnerMode::PerShard,
            sync_interval: 0.1,
            sync_policy: SyncPolicyConfig::adaptive(0.0),
            ..quick(1, DispatchMode::Execute)
        })
        .is_err());
        // A NaN or negative --sync-threshold is rejected even in shared
        // mode, where the adaptive trigger is unused: a poisoned config
        // field must fail loudly, not ride along silently.
        for bad in [f64::NAN, -0.5] {
            assert!(run_plane(PlaneConfig {
                learners: LearnerMode::Shared,
                sync_policy: SyncPolicyConfig {
                    threshold: bad,
                    ..SyncPolicyConfig::periodic()
                },
                ..quick(1, DispatchMode::Execute)
            })
            .is_err());
        }
    }

    fn quick_per_shard(frontends: usize, mode: DispatchMode) -> PlaneConfig {
        PlaneConfig {
            learners: LearnerMode::PerShard,
            sync_interval: 0.1,
            ..quick(frontends, mode)
        }
    }

    #[test]
    fn per_shard_two_shard_run_conserves_and_merges() {
        let report = run_plane(quick_per_shard(2, DispatchMode::Execute)).unwrap();
        assert_eq!(report.learners, LearnerMode::PerShard);
        assert!(report.dispatched > 100, "dispatched {}", report.dispatched);
        // Per-shard completion routing must neither lose nor duplicate:
        // every dispatched task completes exactly once, at exactly one
        // shard's recorder.
        assert_eq!(report.completed, report.dispatched, "tasks lost or duplicated");
        assert_eq!(report.responses.count() as u64, report.completed);
        assert!(
            report.completed_at_stop + report.queued_at_stop as u64 <= report.dispatched,
            "at-stop accounting over-counts"
        );
        assert!(report.benchmarks > 0, "per-shard dispatchers idle");
        assert!(report.sync_epochs >= 2, "sync epochs {}", report.sync_epochs);
        assert_eq!(report.shard_views.len(), 2);
        // Each shard learned from its own slice of the completion stream.
        for (s, views) in report.shard_views.iter().enumerate() {
            assert!(views.iter().any(|v| v.samples > 0), "shard {s} never sampled");
        }
    }

    #[test]
    fn per_shard_published_estimates_are_the_consensus_of_exported_views() {
        let cfg = quick_per_shard(2, DispatchMode::Execute);
        let prior = cfg.speeds.iter().sum::<f64>() / cfg.speeds.len() as f64;
        let report = run_plane(cfg).unwrap();
        let expect = crate::learner::merge_estimates(&report.shard_views, prior);
        for (w, ((_, est), want)) in report.estimates.iter().zip(expect.iter()).enumerate() {
            assert_eq!(
                est.to_bits(),
                want.to_bits(),
                "worker {w}: table {est} != merged views {want}"
            );
        }
    }

    #[test]
    fn per_shard_learns_speed_ordering_without_a_shared_learner() {
        let cfg = PlaneConfig {
            speeds: vec![2.0, 0.4],
            frontends: 2,
            rate: 300.0,
            duration: 2.0,
            mean_demand: 0.004,
            publish_interval: 0.1,
            learners: LearnerMode::PerShard,
            sync_interval: 0.1,
            ..PlaneConfig::default()
        };
        let report = run_plane(cfg).unwrap();
        assert!(report.completed > 100, "completed {}", report.completed);
        let (t0, e0) = report.estimates[0];
        let (t1, e1) = report.estimates[1];
        assert!(
            e0 > e1,
            "consensus failed to order speeds: {e0} vs {e1} (true {t0} vs {t1})"
        );
    }

    #[test]
    fn decide_only_per_shard_consensus_stays_at_prior() {
        // The deterministic 2-shard harness: decide-only produces no
        // completions, so every shard's exported view is (prior, weight 0)
        // at every local publish and every sync epoch must publish exactly
        // the prior consensus — bit-for-bit.
        let cfg = PlaneConfig {
            max_decisions: Some(2_000),
            fake_jobs: false,
            duration: 30.0,
            ..quick_per_shard(2, DispatchMode::DecideOnly)
        };
        let prior = cfg.speeds.iter().sum::<f64>() / cfg.speeds.len() as f64;
        let report = run_plane(cfg).unwrap();
        assert_eq!(report.decisions, 4_000);
        assert_eq!(report.dispatched, 0);
        assert!(report.sync_epochs >= 1);
        for (w, (_, est)) in report.estimates.iter().enumerate() {
            assert_eq!(est.to_bits(), prior.to_bits(), "worker {w} drifted off the prior");
        }
        for views in &report.shard_views {
            for v in views {
                assert_eq!(v.samples, 0);
                assert_eq!(v.mu_hat.to_bits(), prior.to_bits());
            }
        }
    }

    #[test]
    fn adaptive_plane_merges_at_most_once_per_check_epoch() {
        let cfg = PlaneConfig {
            sync_policy: SyncPolicyConfig::adaptive(0.15),
            ..quick_per_shard(2, DispatchMode::Execute)
        };
        let report = run_plane(cfg).unwrap();
        assert_eq!(report.completed, report.dispatched, "tasks lost or duplicated");
        assert!(report.sync_epochs >= 2, "epochs {}", report.sync_epochs);
        assert!(
            report.sync_merges <= report.sync_epochs,
            "merges {} > epochs {}",
            report.sync_merges,
            report.sync_epochs
        );
        assert!(report.sync_merges >= 1, "the drain epoch alone guarantees one merge");
        // The drain epoch is a full merge under every policy: reported
        // estimates are still the consensus of the final shard views.
        let prior = [1.0f64, 0.5, 0.25, 2.0].iter().sum::<f64>() / 4.0;
        let expect = crate::learner::merge_estimates(&report.shard_views, prior);
        for ((_, est), want) in report.estimates.iter().zip(expect.iter()) {
            assert_eq!(est.to_bits(), want.to_bits());
        }
    }

    #[test]
    fn gossip_plane_conserves_tasks_and_counts_pair_merges() {
        let cfg = PlaneConfig {
            sync_policy: SyncPolicyConfig::gossip(),
            ..quick_per_shard(4, DispatchMode::Execute)
        };
        let report = run_plane(cfg).unwrap();
        assert_eq!(report.completed, report.dispatched, "tasks lost or duplicated");
        assert_eq!(report.responses.count() as u64, report.completed);
        assert!(report.sync_epochs >= 2);
        // 4 shards: every gossip round performs 2 pair merges, plus the
        // single full drain merge.
        assert_eq!(report.sync_merges, 2 * (report.sync_epochs - 1) + 1);
    }

    #[test]
    fn per_shard_benchmark_budget_not_multiplied_by_frontends() {
        // §5 throttling regression: four per-shard dispatchers must share
        // the aggregate budget c0·(μ̄ − λ̂) ≤ c0·μ̄, not run at 4× it.
        let cfg = quick_per_shard(4, DispatchMode::Execute);
        let mu_bar = cfg.speeds.iter().sum::<f64>() / cfg.mean_demand;
        let report = run_plane(cfg).unwrap();
        assert!(report.benchmarks > 0, "dispatchers idle");
        let cap = 0.1 * mu_bar * report.elapsed * 1.5 + 20.0;
        assert!(
            (report.benchmarks as f64) < cap,
            "aggregate benchmark rate blew the single-scheduler budget: {} > {cap}",
            report.benchmarks
        );
    }

    #[test]
    fn registry_totals_agree_with_report_and_flight_dump_parses() {
        let path = std::env::temp_dir()
            .join(format!("rosella-flight-test-{}.jsonl", std::process::id()));
        let cfg = PlaneConfig {
            flight_record: Some(path.to_string_lossy().into_owned()),
            ..quick_per_shard(2, DispatchMode::Execute)
        };
        let report = run_plane(cfg).unwrap();
        // The registry saw the exact same stream the report aggregated.
        assert_eq!(report.obs.decisions_total(), report.decisions);
        assert_eq!(report.obs.dispatched_total(), report.dispatched);
        assert_eq!(report.obs.completed_total(), report.completed);
        assert_eq!(report.obs.sync_epochs.get(), report.sync_epochs);
        assert_eq!(report.obs.sync_merges.get(), report.sync_merges);
        assert!(report.obs.arrivals.get() >= report.decisions);
        let agg = report.obs.aggregate(|s| &s.response_us);
        assert_eq!(agg.count(), report.completed, "response histogram lost samples");
        // The exposition of that registry is structurally valid.
        let doc = crate::obs::expo::render(&report.obs);
        assert!(crate::obs::expo::is_well_formed(&doc), "malformed:\n{doc}");
        // The drain-time flight dump is non-empty, line-parseable JSON,
        // and contains both placements and consensus events.
        let dump = std::fs::read_to_string(&path).unwrap();
        let _ = std::fs::remove_file(&path);
        assert!(!dump.is_empty(), "flight dump empty");
        for line in dump.lines() {
            crate::config::parse(line).expect("flight line must be valid JSON");
        }
        assert!(dump.contains("\"placement\""), "no placements in dump");
        assert!(dump.contains("\"consensus\""), "no consensus events in dump");
    }

    #[test]
    fn scrape_endpoint_serves_metrics_and_flight() {
        use std::io::{Read, Write};
        fn http_get(addr: std::net::SocketAddr, path: &str) -> String {
            let mut s = std::net::TcpStream::connect(addr).unwrap();
            write!(s, "GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").unwrap();
            let mut buf = String::new();
            s.read_to_string(&mut buf).unwrap();
            buf
        }
        let obs = Arc::new(crate::obs::Registry::new(1, 2));
        obs.shard(0).completed.add(3);
        let flight = Arc::new(crate::obs::FlightRecorder::new(1, 16));
        flight.record(
            0,
            crate::obs::FlightEvent::Placement {
                t_ns: 10,
                shard: 0,
                task: 1,
                probed: vec![(0, 4), (1, 2)],
                chosen: 1,
                mu_chosen: 1.5,
                lambda_hat: 100.0,
                decision_ns: 80,
            },
        );
        let qlen: Vec<Arc<CachePadded<AtomicUsize>>> =
            (0..2).map(|i| Arc::new(CachePadded::new(AtomicUsize::new(i)))).collect();
        let tracer = Arc::new(crate::obs::Tracer::new(8));
        tracer.record(crate::obs::SpanRecord {
            job: 0,
            origin_us: 5,
            stages_us: [1, 2, 3, 4, 5, 6],
        });
        let srv = spawn_metrics_server(
            "127.0.0.1:0",
            obs,
            Some(flight),
            qlen,
            Some(tracer),
        )
        .unwrap();
        let addr = srv.addr();
        let body = http_get(addr, "/metrics");
        assert!(body.starts_with("HTTP/1.1 200"), "bad response: {body}");
        assert!(body.contains("rosella_tasks_completed_total{shard=\"0\"} 3"));
        assert!(body.contains("rosella_worker_queue_len{worker=\"1\"} 1"));
        assert!(body.contains("rosella_wire_frames_sent_total"));
        assert!(body.contains("rosella_flight_dropped_total 0"));
        assert!(body.contains("rosella_stage_us"), "stage histograms missing: {body}");
        // Topology gauges are served even with pinning off: −1 sentinel,
        // never a missing series.
        assert!(body.contains("rosella_shard_cpu{shard=\"0\"} -1"));
        assert!(body.contains("rosella_cross_socket_decisions_total{shard=\"0\"} 0"));
        let fl = http_get(addr, "/flight");
        assert!(fl.contains("\"chosen\""), "flight route missing event: {fl}");
        let tr = http_get(addr, "/trace");
        assert!(tr.contains("traceEvents"), "trace route missing spans: {tr}");
        assert!(http_get(addr, "/nope").starts_with("HTTP/1.1 404"));
        srv.shutdown();
    }

    #[test]
    fn bench_json_is_parseable_and_complete() {
        let base = quick(1, DispatchMode::DecideOnly);
        let cfg = PlaneConfig {
            max_decisions: Some(200),
            fake_jobs: false,
            duration: 30.0,
            ..base.clone()
        };
        let reports = vec![run_plane(cfg).unwrap()];
        let doc = crate::config::to_string(&bench_json(&base, &reports));
        let back = crate::config::parse(&doc).expect("bench json must round-trip");
        match back {
            crate::config::Json::Obj(m) => {
                assert!(m.contains_key("results"));
                assert!(m.contains_key("bench"));
            }
            other => panic!("unexpected {other:?}"),
        }
    }
}
