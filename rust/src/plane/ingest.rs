//! Batched task ingestion for plane frontends.
//!
//! Each frontend consumes its own Poisson arrival stream. Generating and
//! dispatching arrivals one at a time costs two RNG draws, an estimator
//! update, and a clock read per task; batching amortizes that bookkeeping:
//! the batcher materializes the next `batch` arrivals (timestamps and
//! service demands) in one call, and the shard loop then walks the batch,
//! sleeping only until each arrival is due. The stream itself is identical
//! to the unbatched one — batching changes *when work is generated*, never
//! the arrival process — and is a pure function of the RNG seed, which is
//! what makes single-shard plane runs reproducible decision-for-decision.

use crate::stats::{Exponential, Rng};

/// One generated arrival: when it lands and how much work it carries.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Arrival {
    /// Arrival time in seconds since the plane started.
    pub at: f64,
    /// Service demand in unit-speed seconds (floored at 0.1 ms).
    pub demand: f64,
}

/// Poisson arrival-batch generator for one frontend shard.
#[derive(Debug, Clone)]
pub struct ArrivalBatcher {
    gap: Exponential,
    demand: Exponential,
    /// Time of the last generated arrival (seconds since plane start).
    t: f64,
    batch: usize,
    /// Total arrivals generated over the batcher's lifetime. Plain (not
    /// atomic): the batcher lives on one shard thread; the shard exports
    /// the count to the shared [`crate::obs::Registry`] after each fill.
    generated: u64,
}

impl ArrivalBatcher {
    /// Stream with `rate` arrivals/second and exponential demands of mean
    /// `mean_demand`, generated `batch` arrivals at a time.
    pub fn new(rate: f64, mean_demand: f64, batch: usize) -> Self {
        assert!(rate > 0.0 && mean_demand > 0.0 && batch >= 1);
        Self {
            gap: Exponential::new(rate),
            demand: Exponential::with_mean(mean_demand),
            t: 0.0,
            batch,
            generated: 0,
        }
    }

    /// Configured batch size.
    pub fn batch(&self) -> usize {
        self.batch
    }

    /// Total arrivals generated so far (ingest-side observability).
    pub fn generated(&self) -> u64 {
        self.generated
    }

    /// Clear `out` and fill it with the next `batch` arrivals, in
    /// increasing time order.
    pub fn fill(&mut self, rng: &mut Rng, out: &mut Vec<Arrival>) {
        out.clear();
        for _ in 0..self.batch {
            self.t += self.gap.sample(rng);
            out.push(Arrival { at: self.t, demand: self.demand.sample(rng).max(1e-4) });
        }
        self.generated += self.batch as u64;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn batches_have_requested_size_and_monotone_times() {
        let mut b = ArrivalBatcher::new(100.0, 0.01, 64);
        let mut rng = Rng::new(5);
        let mut out = Vec::new();
        let mut last = 0.0;
        for _ in 0..10 {
            b.fill(&mut rng, &mut out);
            assert_eq!(out.len(), 64);
            for a in &out {
                assert!(a.at > last, "non-monotone arrival times");
                assert!(a.demand >= 1e-4);
                last = a.at;
            }
        }
    }

    #[test]
    fn stream_rate_matches_configuration() {
        let mut b = ArrivalBatcher::new(250.0, 0.02, 128);
        let mut rng = Rng::new(6);
        let mut out = Vec::new();
        let mut count = 0usize;
        let mut end = 0.0;
        let mut demand_sum = 0.0;
        for _ in 0..200 {
            b.fill(&mut rng, &mut out);
            count += out.len();
            end = out.last().unwrap().at;
            demand_sum += out.iter().map(|a| a.demand).sum::<f64>();
        }
        let rate = count as f64 / end;
        assert!((rate - 250.0).abs() < 10.0, "rate={rate}");
        let mean_demand = demand_sum / count as f64;
        assert!((mean_demand - 0.02).abs() < 0.002, "mean demand {mean_demand}");
    }

    #[test]
    fn deterministic_for_fixed_seed() {
        let mut a = ArrivalBatcher::new(50.0, 0.1, 32);
        let mut b = ArrivalBatcher::new(50.0, 0.1, 32);
        let mut ra = Rng::new(77);
        let mut rb = Rng::new(77);
        let (mut va, mut vb) = (Vec::new(), Vec::new());
        for _ in 0..5 {
            a.fill(&mut ra, &mut va);
            b.fill(&mut rb, &mut vb);
            assert_eq!(va, vb);
        }
    }

    #[test]
    #[should_panic]
    fn zero_batch_rejected() {
        ArrivalBatcher::new(1.0, 0.1, 0);
    }

    #[test]
    fn generated_counter_tracks_fills() {
        let mut b = ArrivalBatcher::new(10.0, 0.1, 16);
        let mut rng = Rng::new(1);
        let mut out = Vec::new();
        assert_eq!(b.generated(), 0);
        b.fill(&mut rng, &mut out);
        b.fill(&mut rng, &mut out);
        assert_eq!(b.generated(), 32);
    }
}
