//! Frontend shards: the per-thread Rosella loop of the sharded plane.
//!
//! [`FrontendCore`] bundles exactly the state one scheduler frontend owns —
//! a policy instance, an RNG, an arrival estimator, and a cache of the last
//! published estimates — and exposes the scheduling decision two ways:
//!
//! * [`FrontendCore::decide_local`] over borrowed slices (the live
//!   coordinator's single-frontend path);
//! * [`FrontendCore::decide_shared`] over the plane's lock-free shared
//!   state (atomic queue probes + seqlock estimate cache).
//!
//! Both paths run the *same* policy code against the same RNG stream, which
//! is what makes a single-shard plane run reproduce the live coordinator's
//! placement sequence decision-for-decision for a fixed seed.
//!
//! With per-shard learners ([`super::LearnerMode::PerShard`]) the shard
//! thread additionally owns the full §5 scheduler learning stack
//! (`ShardLearnState`): a private [`PerfLearner`] fed by this shard's own
//! completion channel, a benchmark dispatcher running at the throttled
//! per-scheduler rate `c0(μ̄ − λ̂)/k`, and the periodic view export that
//! feeds estimate-sync consensus.

use super::consensus::SharedViews;
use super::ingest::ArrivalBatcher;
use super::state::{CachePadded, EstimateCache, EstimateTable, SharedView};
use super::DispatchMode;
use crate::coordinator::worker::{Completion, LiveTask, WorkerClient};
use crate::learner::{ArrivalEstimator, EstimateView, FakeJobDispatcher, PerfLearner};
use crate::metrics::ResponseRecorder;
use crate::scheduler::{Policy, PolicyKind};
use crate::stats::{Exponential, Rng, SplitMix64};
use crate::types::{ClusterView, JobPlacement, JobSpec, LocalView, TaskKind, WorkerId};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Bits reserved for the within-shard job counter; the shard id lives in
/// the bits above. 2^48 jobs per shard is unreachable in practice.
pub const SHARD_SHIFT: u32 = 48;

/// Encode a (shard, local job counter) pair into a task's job id.
#[inline]
pub fn encode_job(shard: usize, local: u64) -> u64 {
    debug_assert!(local < (1u64 << SHARD_SHIFT));
    ((shard as u64) << SHARD_SHIFT) | local
}

/// Shard that dispatched the job with this id.
#[inline]
pub fn job_shard(job: u64) -> usize {
    (job >> SHARD_SHIFT) as usize
}

/// Local job id reserved for a shard's own benchmark tasks: completion
/// routing only needs the shard bits, and the sentinel keeps benchmark ids
/// disjoint from real job counters.
pub const BENCH_LOCAL_JOB: u64 = (1u64 << SHARD_SHIFT) - 1;

/// Deterministic per-shard seed schedule: `(core_seed, stream_seed)` for
/// shard `i` of a plane seeded with `seed`. The core seed drives the policy
/// RNG; the stream seed drives the arrival/demand stream.
pub fn shard_seeds(seed: u64, shard: usize) -> (u64, u64) {
    let mut sm = SplitMix64::new(seed);
    let mut pair = (sm.next_u64(), sm.next_u64());
    for _ in 0..shard {
        pair = (sm.next_u64(), sm.next_u64());
    }
    pair
}

/// One scheduler frontend's complete decision state.
pub struct FrontendCore {
    policy: Box<dyn Policy>,
    rng: Rng,
    arrivals: ArrivalEstimator,
    cache: EstimateCache,
    /// Mean task demand τ̄ — converts λ̂ (tasks/s) into the service-rate
    /// units `Policy::on_estimates` expects.
    mean_demand: f64,
}

impl FrontendCore {
    /// New frontend for `n` workers with the given prior estimate.
    pub fn new(
        kind: &PolicyKind,
        n: usize,
        prior: f64,
        mean_demand: f64,
        arrival_window: usize,
        seed: u64,
    ) -> Self {
        assert!(n > 0 && prior >= 0.0 && mean_demand > 0.0);
        let mut policy = kind.build(n);
        let cache = EstimateCache::new(n, prior);
        policy.on_estimates(&cache.mu_hat, 0.0);
        Self {
            policy,
            rng: Rng::new(seed),
            arrivals: ArrivalEstimator::new(arrival_window),
            cache,
            mean_demand,
        }
    }

    /// Feed the frontend's own arrival stream (estimator input).
    pub fn on_arrival(&mut self, now: f64, tasks: usize) {
        self.arrivals.on_arrival(now, tasks);
    }

    /// This frontend's arrival-rate estimate λ̂ (tasks/second).
    pub fn lambda_or(&self, default: f64) -> f64 {
        self.arrivals.lambda_or(default)
    }

    /// The plane-aggregate λ̂ cached from the last estimate-table refresh
    /// (tasks/second; 0 before the first publish). Per-shard learners use
    /// it for the §5 throttled probing rate and the learner window, so all
    /// schedulers derive their parameters from the synchronized load
    /// estimate rather than their 1/k-th slice of it.
    pub fn cached_lambda(&self) -> f64 {
        self.cache.lambda_tasks
    }

    /// Current cached speed estimates.
    pub fn mu_hat(&self) -> &[f64] {
        &self.cache.mu_hat
    }

    /// Policy name (reports).
    pub fn policy_name(&self) -> String {
        self.policy.name()
    }

    /// Install fresh estimates directly (single-frontend drivers own the
    /// learner and push to their core; plane frontends pull via
    /// [`Self::maybe_refresh`] instead).
    pub fn set_estimates(&mut self, mu_hat: &[f64], lambda_tasks: f64) {
        self.cache.mu_hat.clear();
        self.cache.mu_hat.extend_from_slice(mu_hat);
        self.cache.sampler.rebuild(&self.cache.mu_hat);
        self.cache.lambda_tasks = lambda_tasks;
        self.policy.on_estimates(&self.cache.mu_hat, lambda_tasks * self.mean_demand);
    }

    /// Re-read the shared estimate table iff its epoch moved since the last
    /// refresh. The no-change case — the per-decision hot path — is a
    /// single atomic load. Returns whether a refresh happened.
    pub fn maybe_refresh(&mut self, table: &EstimateTable) -> bool {
        if table.epoch() == self.cache.epoch {
            return false;
        }
        let (lambda, epoch) = table.read(&mut self.cache.mu_hat);
        self.cache.epoch = epoch;
        self.cache.lambda_tasks = lambda;
        // In-place sampler rebuild: a publish refresh allocates nothing.
        self.cache.sampler.rebuild(&self.cache.mu_hat);
        self.policy.on_estimates(&self.cache.mu_hat, lambda * self.mean_demand);
        true
    }

    /// Schedule one job against borrowed queue lengths (the live
    /// coordinator's path). Single-task jobs are the serving case;
    /// reservation placements degrade to the first probe.
    pub fn decide_local(&mut self, job: &JobSpec, qlen: &[usize]) -> WorkerId {
        self.decide_local_traced(job, qlen, None)
    }

    /// [`Self::decide_local`] with an optional probe trace attached
    /// (decision flight recorder). As with the shared path, the policy code
    /// and its RNG stream are identical with or without the trace.
    pub fn decide_local_traced(
        &mut self,
        job: &JobSpec,
        qlen: &[usize],
        trace: Option<&crate::obs::ProbeTrace>,
    ) -> WorkerId {
        let view = LocalView {
            queue_len: qlen,
            mu_hat: &self.cache.mu_hat,
            sampler: &self.cache.sampler,
            lambda_hat: self.arrivals.lambda_or(0.0),
        };
        let placement = match trace {
            Some(trace) => {
                let traced = TracedView { inner: view, trace };
                self.policy.schedule_job(job, &traced, &mut self.rng)
            }
            None => self.policy.schedule_job(job, &view, &mut self.rng),
        };
        flatten(placement)
    }

    /// Schedule one job against the plane's shared state: atomic probes,
    /// cached estimates, no locks, no copies.
    pub fn decide_shared(
        &mut self,
        job: &JobSpec,
        qlen: &[Arc<CachePadded<AtomicUsize>>],
    ) -> WorkerId {
        self.decide_shared_traced(job, qlen, None)
    }

    /// [`Self::decide_shared`] with an optional probe trace attached to
    /// the view (decision flight recorder). The policy code and its RNG
    /// stream are identical with or without the trace — capture is a pure
    /// side channel on `queue_len` reads.
    pub fn decide_shared_traced(
        &mut self,
        job: &JobSpec,
        qlen: &[Arc<CachePadded<AtomicUsize>>],
        trace: Option<&crate::obs::ProbeTrace>,
    ) -> WorkerId {
        let view = SharedView { qlen, est: &self.cache, trace };
        flatten(self.policy.schedule_job(job, &view, &mut self.rng))
    }

    /// Socket-local power-of-two-choices: probe two workers drawn from
    /// `group` (this shard's same-package partition) and dispatch to the
    /// shorter queue — touching only package-local cache lines — unless
    /// that queue exceeds `spill_threshold`, in which case fall back to the
    /// configured policy over the full view ([`Self::decide_shared`]).
    /// Returns the chosen worker and whether the decision spilled
    /// cross-socket. Only the plane's `--pin sockets` mode reaches this
    /// path; `none`/`cores` keep the exact pre-existing decision stream.
    pub fn decide_shared_grouped(
        &mut self,
        job: &JobSpec,
        qlen: &[Arc<CachePadded<AtomicUsize>>],
        group: &[usize],
        spill_threshold: usize,
    ) -> (WorkerId, bool) {
        debug_assert!(!group.is_empty(), "grouped decision over an empty worker group");
        let a = group[self.rng.gen_index(group.len())];
        let b = group[self.rng.gen_index(group.len())];
        let qa = qlen[a].load(Ordering::Relaxed);
        let qb = qlen[b].load(Ordering::Relaxed);
        let (w, q) = if qb < qa { (b, qb) } else { (a, qa) };
        if q <= spill_threshold {
            (w, false)
        } else {
            // Local group backed up: pay the cross-socket probes rather
            // than pile onto a saturated package (the heterogeneity
            // argument applied to memory distance).
            (self.decide_shared(job, qlen), true)
        }
    }
}

/// [`ClusterView`] adapter mirroring every queue-length read into a
/// [`crate::obs::ProbeTrace`] — how the flight recorder captures probes on
/// the slice-backed [`LocalView`] path without widening [`LocalView`]
/// itself (its other users — DES, hotpath, policy tests — stay untouched).
struct TracedView<'a, V> {
    inner: V,
    trace: &'a crate::obs::ProbeTrace,
}

impl<V: ClusterView> ClusterView for TracedView<'_, V> {
    fn n(&self) -> usize {
        self.inner.n()
    }

    #[inline]
    fn queue_len(&self, w: WorkerId) -> usize {
        let q = self.inner.queue_len(w);
        self.trace.push(w, q);
        q
    }

    #[inline]
    fn mu_hat(&self, w: WorkerId) -> f64 {
        self.inner.mu_hat(w)
    }

    fn lambda_hat(&self) -> f64 {
        self.inner.lambda_hat()
    }

    #[inline]
    fn sample(&self, rng: &mut crate::stats::Rng) -> WorkerId {
        self.inner.sample(rng)
    }
}

/// Collapse a placement to one worker (plane/coordinator serve single-task
/// jobs; reservation policies degrade to their first probe).
#[inline]
fn flatten(placement: JobPlacement) -> WorkerId {
    match placement {
        JobPlacement::Single(w) => w,
        JobPlacement::PerTask(ws) => ws[0],
        JobPlacement::Reservations(ws) => ws[0],
    }
}

/// Everything one shard thread needs, owned.
pub(crate) struct ShardRun {
    pub id: usize,
    pub policy: PolicyKind,
    pub n: usize,
    pub prior: f64,
    pub mean_demand: f64,
    /// This shard's arrival rate (the aggregate rate split across shards).
    pub rate: f64,
    pub batch: usize,
    pub seed: u64,
    pub mode: DispatchMode,
    pub max_decisions: Option<u64>,
    pub record_placements: bool,
    pub workers: Vec<WorkerClient>,
    pub qlen: Vec<Arc<CachePadded<AtomicUsize>>>,
    pub table: Arc<EstimateTable>,
    /// CPU this shard thread pins itself to (`None` = leave to the OS).
    pub cpu: Option<usize>,
    /// Same-package worker ids for socket-local probing. Empty = probe the
    /// full view exactly as before (`--pin none`/`cores`, single socket).
    pub group: Vec<usize>,
    /// Local-group queue length above which a grouped decision spills to
    /// the full cross-socket view.
    pub spill_threshold: usize,
    /// f64-bit slot where this shard publishes its λ̂ for the sync side.
    pub lambda_slot: Arc<AtomicU64>,
    pub stop: Arc<AtomicBool>,
    /// Bumped once when this shard leaves its decision loop, so the plane
    /// driver can distinguish "done deciding" from "thread finished" (a
    /// per-shard drain keeps the thread alive until the pool exits).
    pub done_deciding: Arc<AtomicUsize>,
    pub start: Instant,
    /// Minimum guaranteed total throughput μ̄ (tasks/s) — per-shard learner
    /// and dispatcher parameter.
    pub mu_bar: f64,
    /// Local learner publish/view-export cadence (seconds).
    pub publish_interval: f64,
    /// Warmup cutoff for this shard's response recorder.
    pub warmup: f64,
    /// Whether this shard runs its own benchmark dispatcher (per-shard
    /// learners, Execute mode only).
    pub fake_jobs: bool,
    /// Total scheduler count k (the §5 probing-budget divisor).
    pub shards: usize,
    /// Adaptive sync: request a merge when this shard's local estimates
    /// diverge from the last adopted consensus beyond this relative-error
    /// threshold (`None` = non-adaptive policy, never computed).
    pub divergence_threshold: Option<f64>,
    /// Per-shard learning plumbing; `None` runs the legacy shared-learner
    /// shard loop (the aggregator owns all learning state).
    pub learner: Option<ShardLearner>,
    /// Run-wide metrics registry; this shard writes only slot `id`
    /// (uncontended relaxed atomics — the always-on telemetry surface).
    pub obs: Arc<crate::obs::Registry>,
    /// Decision flight recorder (opt-in; adds two clock reads and a probe
    /// trace per decision when present).
    pub flight: Option<Arc<crate::obs::FlightRecorder>>,
    /// Lifecycle tracer (opt-in; records a queue/service/reply span for the
    /// deterministic 1-in-N sample of completed real tasks).
    pub tracer: Option<Arc<crate::obs::Tracer>>,
}

/// The channels a per-shard learner consumes and feeds.
pub(crate) struct ShardLearner {
    /// This shard's own completion channel (node monitors route by job id).
    pub comp_rx: Receiver<Completion>,
    /// Where the shard exports learner views for estimate-sync consensus.
    pub views: Arc<SharedViews>,
    /// Every shard's live λ̂ slot — the bootstrap for the benchmark
    /// throttle and learner window until the first consensus publish puts
    /// an exchanged λ̂_global in the table (before that,
    /// `cached_lambda()` is 0 and the dispatcher would run unthrottled).
    pub lambda_slots: Vec<Arc<AtomicU64>>,
    /// Plane-wide completed-real counter (conservation accounting).
    pub completed_real: Arc<AtomicU64>,
}

/// What a shard reports back when it stops.
#[derive(Debug)]
pub(crate) struct ShardStats {
    pub decisions: u64,
    pub dispatched: u64,
    pub placements: Vec<WorkerId>,
    /// This shard's own latency recorder (per-shard learners; empty under a
    /// shared aggregator, which records responses centrally).
    pub responses: ResponseRecorder,
    /// Benchmark tasks this shard's dispatcher injected.
    pub benchmarks: u64,
    /// Final exported learner view (per-shard learners; empty otherwise).
    pub views: Vec<EstimateView>,
}

/// Cap on recorded placements (test instrumentation, not a metric).
const MAX_RECORDED: usize = 100_000;

/// The full §5 scheduler learning stack owned by one shard thread: private
/// learner, throttled benchmark dispatcher, latency recorder, and the
/// periodic view export feeding estimate-sync consensus.
struct ShardLearnState {
    comp_rx: Receiver<Completion>,
    views: Arc<SharedViews>,
    lambda_slots: Vec<Arc<AtomicU64>>,
    completed_real: Arc<AtomicU64>,
    perf: PerfLearner,
    dispatcher: FakeJobDispatcher,
    demand_dist: Exponential,
    rng: Rng,
    responses: ResponseRecorder,
    benchmarks: u64,
    next_publish: Instant,
    next_bench: Instant,
    view_buf: Vec<EstimateView>,
    shard: usize,
    publish_interval: f64,
}

impl ShardLearnState {
    fn new(l: ShardLearner, ctx: &ShardRun, learn_seed: u64) -> Self {
        // Same constants the shared aggregator uses (c = 10, c0 = 0.1), so
        // shared vs per-shard compares learning topology, nothing else.
        // `shared_among(k)` scales the window requirement to this shard's
        // 1/k share of the completion stream.
        let perf = PerfLearner::new(ctx.n, 10.0, ctx.mean_demand, ctx.mu_bar, ctx.prior, 0.0)
            .shared_among(ctx.shards);
        let dispatcher = FakeJobDispatcher::new_sharded(
            0.1,
            ctx.mu_bar,
            ctx.fake_jobs && ctx.mode == DispatchMode::Execute,
            ctx.shards,
        );
        Self {
            comp_rx: l.comp_rx,
            views: l.views,
            lambda_slots: l.lambda_slots,
            completed_real: l.completed_real,
            perf,
            dispatcher,
            demand_dist: Exponential::with_mean(ctx.mean_demand),
            rng: Rng::new(learn_seed),
            responses: ResponseRecorder::new(ctx.warmup),
            benchmarks: 0,
            next_publish: ctx.start + Duration::from_secs_f64(ctx.publish_interval),
            next_bench: ctx.start + Duration::from_secs_f64(0.05),
            view_buf: Vec::with_capacity(ctx.n),
            shard: ctx.id,
            publish_interval: ctx.publish_interval,
        }
    }

    /// λ̂_global this shard's learning stack runs on: the exchanged value
    /// from the last consensus publish, or — before the first publish puts
    /// one in the table — the live sum of every shard's λ̂ slot (the same
    /// bootstrap the DES engine uses, so the §5 throttle never runs
    /// against an assumed zero load).
    fn lambda_global(&self, core: &FrontendCore) -> f64 {
        let cached = core.cached_lambda();
        if cached > 0.0 {
            cached
        } else {
            super::consensus::lambda_total(&self.lambda_slots)
        }
    }

    /// Absorb one completion report of a task this shard routed.
    fn record(&mut self, ctx: &ShardRun, c: &Completion) {
        let now_s = (c.at - ctx.start).as_secs_f64();
        self.perf.on_completion(c.worker, now_s, c.duration.max(1e-6), c.demand);
        if c.kind == TaskKind::Real {
            self.responses.record((now_s - c.sojourn).max(0.0), now_s);
            let slot = ctx.obs.shard(self.shard);
            slot.completed.inc();
            slot.response_us.record((c.sojourn.max(0.0) * 1e6) as u64);
            if let Some(tr) = ctx.tracer.as_ref() {
                tr.record_completion(c.job, c.queue_wait(), c.duration, c.at);
            }
            // Release pairs with the Acquire load in `run_plane`'s stop
            // snapshot: a task counted here already left its queue probe.
            self.completed_real.fetch_add(1, Ordering::Release);
        }
    }

    /// Publish the local learner and export its sync payload — estimate
    /// views plus this scheduler's local arrival share λ̂ₛ (the consensus
    /// sums the exchanged shares into λ̂_global). Under an adaptive sync
    /// policy, also run the §5 divergence test: if the local estimates
    /// drifted beyond the threshold from the last adopted consensus
    /// (`core.mu_hat()`, the cached table read), request a merge.
    fn publish_and_export(&mut self, ctx: &ShardRun, core: &FrontendCore) {
        let now_s = ctx.start.elapsed().as_secs_f64();
        let lambda = self.lambda_global(core);
        self.perf.publish(now_s, lambda);
        self.perf.export_views_into(&mut self.view_buf);
        self.views.store(self.shard, &self.view_buf, core.lambda_or(0.0));
        ctx.obs.sync_exports.inc();
        if let Some(threshold) = ctx.divergence_threshold {
            if self.perf.divergence_from(core.mu_hat()) > threshold {
                self.views.request_merge();
            }
        }
    }

    /// The off-hot-path learner duties, run between decisions: drain this
    /// shard's completion channel, dispatch benchmark jobs at the throttled
    /// per-scheduler rate, and publish/export on the local cadence.
    fn tick(&mut self, ctx: &ShardRun, core: &FrontendCore) {
        while let Ok(c) = self.comp_rx.try_recv() {
            self.record(ctx, &c);
        }
        let lambda = self.lambda_global(core);
        let injected = super::dispatch_benchmarks(
            &self.dispatcher,
            &ctx.workers,
            lambda,
            encode_job(self.shard, BENCH_LOCAL_JOB),
            &self.demand_dist,
            &mut self.rng,
            &mut self.next_bench,
        );
        self.benchmarks += injected;
        if injected > 0 {
            ctx.obs.shard(self.shard).bench_dispatched.add(injected);
        }
        if Instant::now() >= self.next_publish {
            self.publish_and_export(ctx, core);
            self.next_publish += Duration::from_secs_f64(self.publish_interval);
        }
    }

    /// Adopt the freshly refreshed consensus into the private learner
    /// (called only when the table epoch moved — sync epochs, not per
    /// decision).
    fn adopt_consensus(&mut self, core: &FrontendCore) {
        self.perf.adopt(core.mu_hat());
    }

    /// Post-stop drain: keep absorbing completions until every node
    /// monitor has exited and the channel disconnects, then publish the
    /// final view so the closing consensus epoch sees every sample.
    fn drain(&mut self, ctx: &ShardRun, core: &FrontendCore) {
        loop {
            match self.comp_rx.recv_timeout(Duration::from_millis(5)) {
                Ok(c) => {
                    self.record(ctx, &c);
                    while let Ok(c) = self.comp_rx.try_recv() {
                        self.record(ctx, &c);
                    }
                }
                Err(RecvTimeoutError::Timeout) => {}
                Err(RecvTimeoutError::Disconnected) => break,
            }
        }
        self.publish_and_export(ctx, core);
    }
}

/// The shard thread body: the full Rosella frontend loop.
pub(crate) fn run_shard(mut ctx: ShardRun) -> ShardStats {
    // Best-effort pinning before any work: the gauge reports the CPU only
    // when the kernel actually accepted the mask (−1 otherwise, so
    // dashboards can tell "requested but denied" from "pinned").
    if let Some(cpu) = ctx.cpu {
        if super::topo::pin_current_thread(cpu) {
            ctx.obs.shard(ctx.id).shard_cpu.set(cpu as f64);
        }
    }
    let (core_seed, stream_seed) = shard_seeds(ctx.seed, ctx.id);
    let mut core =
        FrontendCore::new(&ctx.policy, ctx.n, ctx.prior, ctx.mean_demand, 128, core_seed);
    let mut stream_rng = Rng::new(stream_seed);
    let mut batcher = ArrivalBatcher::new(ctx.rate, ctx.mean_demand, ctx.batch);
    let mut batch = Vec::with_capacity(ctx.batch);
    // Reused single-task job spec: no allocation per decision.
    let mut job = JobSpec::single(ctx.mean_demand);
    let mut stats = ShardStats {
        decisions: 0,
        dispatched: 0,
        placements: Vec::new(),
        responses: ResponseRecorder::new(ctx.warmup),
        benchmarks: 0,
        views: Vec::new(),
    };
    let mut local_jobs: u64 = 0;
    // Per-shard learning stack (None = the shared aggregator owns it). Its
    // RNG stream is independent of the decision/arrival streams, so the
    // decision sequence stays a pure function of the seed schedule.
    let mut learn = ctx
        .learner
        .take()
        .map(|l| ShardLearnState::new(l, &ctx, core_seed ^ stream_seed ^ 0xFA_CE));
    // Telemetry: this shard's private registry slot (relaxed atomics, no
    // contention) and, when the flight recorder is on, the probe trace the
    // decision view fills in. Neither touches an RNG stream.
    let obs = ctx.obs.clone();
    let slot = obs.shard(ctx.id);
    let flight = ctx.flight.clone();
    let trace = crate::obs::ProbeTrace::new();

    'outer: while !ctx.stop.load(Ordering::Relaxed) {
        batcher.fill(&mut stream_rng, &mut batch);
        obs.arrivals.add(batch.len() as u64);
        for a in &batch {
            if let Some(maxd) = ctx.max_decisions {
                if stats.decisions >= maxd {
                    break 'outer;
                }
            }
            if ctx.mode == DispatchMode::Execute {
                // Pace the batch: dispatch each arrival when it is due,
                // servicing the learner duties while waiting.
                loop {
                    if let Some(ls) = learn.as_mut() {
                        ls.tick(&ctx, &core);
                    }
                    let elapsed = ctx.start.elapsed().as_secs_f64();
                    if elapsed >= a.at {
                        break;
                    }
                    if ctx.stop.load(Ordering::Relaxed) {
                        break 'outer;
                    }
                    std::thread::sleep(Duration::from_secs_f64((a.at - elapsed).min(1e-3)));
                }
            }
            core.on_arrival(a.at, 1);
            if core.maybe_refresh(&ctx.table) {
                // A fresh consensus arrived (sync epoch): adopt it into the
                // private learner. Never taken on the no-change hot path.
                if let Some(ls) = learn.as_mut() {
                    ls.adopt_consensus(&core);
                }
            }
            job.tasks[0].demand = a.demand;
            let w = if !ctx.group.is_empty() {
                // Socket-local probing (`--pin sockets`, ≥ 2 packages):
                // SQ(2) over this shard's same-package workers, spilling
                // to the full-view policy only past the threshold.
                let (w, spilled) =
                    core.decide_shared_grouped(&job, &ctx.qlen, &ctx.group, ctx.spill_threshold);
                if spilled {
                    slot.cross_socket.inc();
                }
                w
            } else {
                match flight.as_deref() {
                    None => core.decide_shared(&job, &ctx.qlen),
                    Some(rec) => {
                        // Flight-recorded decision: same policy code and
                        // RNG stream, plus probe capture and a latency
                        // clock.
                        trace.clear();
                        let t0 = Instant::now();
                        let w = core.decide_shared_traced(&job, &ctx.qlen, Some(&trace));
                        let decision_ns = t0.elapsed().as_nanos() as u64;
                        slot.decision_ns.record(decision_ns);
                        rec.record(
                            ctx.id,
                            crate::obs::FlightEvent::Placement {
                                t_ns: ctx.start.elapsed().as_nanos() as u64,
                                shard: ctx.id as u32,
                                task: encode_job(ctx.id, local_jobs),
                                probed: trace.probes(),
                                chosen: w as u32,
                                mu_chosen: core.mu_hat()[w],
                                lambda_hat: core.cached_lambda(),
                                decision_ns,
                            },
                        );
                        w
                    }
                }
            };
            stats.decisions += 1;
            slot.decisions.inc();
            slot.queue_len.record(ctx.qlen[w].load(Ordering::Relaxed) as u64);
            if ctx.record_placements && stats.placements.len() < MAX_RECORDED {
                stats.placements.push(w);
            }
            if ctx.mode == DispatchMode::Execute {
                ctx.workers[w].enqueue(LiveTask {
                    job: encode_job(ctx.id, local_jobs),
                    kind: TaskKind::Real,
                    demand: a.demand,
                    enqueued: ctx.start + Duration::from_secs_f64(a.at),
                });
                local_jobs += 1;
                stats.dispatched += 1;
                slot.dispatched.inc();
            }
            ctx.lambda_slot.store(core.lambda_or(0.0).to_bits(), Ordering::Relaxed);
        }
        // Decide-only runs service the learner once per batch — off the
        // per-decision path, so raw decision throughput stays unperturbed.
        if ctx.mode != DispatchMode::Execute {
            if let Some(ls) = learn.as_mut() {
                ls.tick(&ctx, &core);
            }
        }
    }
    ctx.done_deciding.fetch_add(1, Ordering::Relaxed);
    if let Some(mut ls) = learn {
        // Release our ingress handles so the worker pool can drain and
        // exit; its exit disconnects our completion channel and ends the
        // drain below.
        ctx.workers.clear();
        ls.drain(&ctx, &core);
        stats.responses = ls.responses;
        stats.benchmarks = ls.benchmarks;
        stats.views = ls.view_buf;
    }
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn job_id_encoding_round_trips() {
        for shard in [0usize, 1, 7, 255] {
            for local in [0u64, 1, 999_999] {
                let id = encode_job(shard, local);
                assert_eq!(job_shard(id), shard);
                assert_eq!(id & ((1 << SHARD_SHIFT) - 1), local);
            }
        }
    }

    #[test]
    fn shard_seed_schedule_is_deterministic_and_distinct() {
        let a = shard_seeds(42, 0);
        let b = shard_seeds(42, 0);
        assert_eq!(a, b);
        let c = shard_seeds(42, 1);
        assert_ne!(a, c);
        assert_ne!(shard_seeds(43, 0), a);
    }

    #[test]
    fn local_and_shared_views_yield_identical_decision_streams() {
        // The plane's lock-free view must be decision-equivalent to the
        // coordinator's borrowed-slice view when probes and estimates agree.
        let kind = PolicyKind::PPoT { tie: crate::scheduler::TieRule::Sq2, late_binding: false };
        let n = 6;
        let mut a = FrontendCore::new(&kind, n, 1.0, 0.01, 128, 99);
        let mut b = FrontendCore::new(&kind, n, 1.0, 0.01, 128, 99);
        let zeros = vec![0usize; n];
        let shared: Vec<Arc<CachePadded<AtomicUsize>>> =
            (0..n).map(|_| Arc::new(CachePadded::new(AtomicUsize::new(0)))).collect();
        let job = JobSpec::single(0.02);
        for k in 0..2_000 {
            let t = k as f64 * 0.001;
            a.on_arrival(t, 1);
            b.on_arrival(t, 1);
            assert_eq!(a.decide_local(&job, &zeros), b.decide_shared(&job, &shared));
        }
    }

    #[test]
    fn refresh_is_noop_until_publish_then_applies() {
        let kind = PolicyKind::Pss;
        let n = 3;
        let table = EstimateTable::new(n, 1.0);
        let mut core = FrontendCore::new(&kind, n, 1.0, 0.1, 64, 5);
        assert!(!core.maybe_refresh(&table), "fresh table must be a no-op");
        table.publish(&[0.0, 0.0, 9.0], 12.0);
        assert!(core.maybe_refresh(&table));
        assert_eq!(core.mu_hat(), &[0.0, 0.0, 9.0]);
        assert!(!core.maybe_refresh(&table), "second refresh must be a no-op");
        // The rebuilt sampler must reflect the new weights.
        let shared: Vec<Arc<CachePadded<AtomicUsize>>> =
            (0..n).map(|_| Arc::new(CachePadded::new(AtomicUsize::new(0)))).collect();
        let job = JobSpec::single(0.1);
        for _ in 0..200 {
            assert_eq!(core.decide_shared(&job, &shared), 2, "all estimate mass on worker 2");
        }
    }

    #[test]
    fn shared_probes_steer_sq2_to_short_queues() {
        let kind = PolicyKind::PPoT { tie: crate::scheduler::TieRule::Sq2, late_binding: false };
        let mut core = FrontendCore::new(&kind, 2, 1.0, 0.1, 64, 11);
        let shared: Vec<Arc<CachePadded<AtomicUsize>>> = vec![
            Arc::new(CachePadded::new(AtomicUsize::new(50))),
            Arc::new(CachePadded::new(AtomicUsize::new(0))),
        ];
        let job = JobSpec::single(0.1);
        let n = 20_000;
        let ones = (0..n)
            .filter(|_| core.decide_shared(&job, &shared) == 1)
            .count();
        // P(choose worker 1) = 1 − P(both probes hit 0) = 3/4.
        assert!((ones as f64 / n as f64 - 0.75).abs() < 0.01, "frac {}", ones as f64 / n as f64);
    }

    fn probes(qs: &[usize]) -> Vec<Arc<CachePadded<AtomicUsize>>> {
        qs.iter().map(|&q| Arc::new(CachePadded::new(AtomicUsize::new(q)))).collect()
    }

    #[test]
    fn grouped_decision_stays_local_below_threshold() {
        let kind = PolicyKind::PPoT { tie: crate::scheduler::TieRule::Sq2, late_binding: false };
        let mut core = FrontendCore::new(&kind, 4, 1.0, 0.1, 64, 3);
        // Group {0, 2} idle, group {1, 3} heavily queued: every decision
        // for the first group's shard must stay in-group and un-spilled.
        let shared = probes(&[0, 50, 1, 50]);
        let job = JobSpec::single(0.1);
        let threshold = super::super::topo::DEFAULT_SPILL_THRESHOLD;
        for _ in 0..1_000 {
            let (w, spilled) = core.decide_shared_grouped(&job, &shared, &[0, 2], threshold);
            assert!(w == 0 || w == 2, "strayed off-group to {w}");
            assert!(!spilled, "spilled with an idle local group");
        }
    }

    #[test]
    fn grouped_decision_spills_only_above_threshold() {
        let kind = PolicyKind::PPoT { tie: crate::scheduler::TieRule::Sq2, late_binding: false };
        let mut core = FrontendCore::new(&kind, 4, 1.0, 0.1, 64, 7);
        let job = JobSpec::single(0.1);
        let threshold = 4;
        // Local group exactly at the threshold: never spills.
        let shared = probes(&[threshold, 0, threshold, 0]);
        for _ in 0..500 {
            let (w, spilled) = core.decide_shared_grouped(&job, &shared, &[0, 2], threshold);
            assert!(!spilled, "spilled at exactly the threshold");
            assert!(w == 0 || w == 2);
        }
        // Local group one past the threshold, other socket idle: every
        // decision spills, and the full-view fallback finds the idle
        // workers the local group cannot see.
        let shared = probes(&[threshold + 1, 0, threshold + 1, 0]);
        let mut spills = 0usize;
        let mut cross = 0usize;
        for _ in 0..2_000 {
            let (w, spilled) = core.decide_shared_grouped(&job, &shared, &[0, 2], threshold);
            spills += spilled as usize;
            cross += (w == 1 || w == 3) as usize;
        }
        assert_eq!(spills, 2_000, "every over-threshold decision must spill");
        // SQ(2) over the full view lands on an idle off-group worker
        // whenever at least one probe hits one (P = 3/4).
        assert!(cross > 1_200, "fallback never reached the idle socket: {cross}");
    }
}
