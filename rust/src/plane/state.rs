//! Lock-free shared state of the sharded scheduling plane.
//!
//! The plane's frontends coordinate through exactly two mechanisms, both
//! lock-free on the per-decision hot path (§2's "minimum coordination"):
//!
//! * **queue-length probes** — each worker owns an
//!   `Arc<CachePadded<AtomicUsize>>` counter (the same probe the live
//!   coordinator uses, padded to its own cache line so one worker's
//!   enqueue/dequeue traffic never invalidates a neighbor's line);
//!   frontends read it with a relaxed atomic load per probe, never copying
//!   the whole vector;
//! * **the estimate table** — a seqlock-published table of speed estimates
//!   μ̂ and the aggregate arrival estimate λ̂, written by the single
//!   aggregator thread and read by every frontend. Frontends poll the
//!   table's epoch (one atomic load per decision) and re-read the table —
//!   rebuilding their local alias sampler — only when it changed, which
//!   happens at the publish interval, not per task.
//!
//! The seqlock follows the standard atomic-data pattern (writer: odd
//! sequence → release fence → data stores → even sequence with release;
//! reader: acquire load → data loads → acquire fence → sequence re-check),
//! with every slot an `AtomicU64` holding f64 bits so there is no unsafe
//! code and no possibility of a data race — the sequence check only guards
//! against mixing elements from two publishes.

use crate::stats::AliasTable;
use crate::types::{ClusterView, WorkerId};
use std::sync::atomic::{fence, AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pads its contents to a 64-byte cache line so two [`CachePadded`] values
/// can never share one.
///
/// This is a pure layout attribute: `#[repr(align(64))]` changes where the
/// value sits in memory, not what any load, store, or RMW on it does, so
/// wrapping an atomic cannot alter program behavior — only the coherence
/// traffic pattern. No `unsafe` is involved anywhere. `Deref`/`DerefMut`
/// make the wrapper transparent at call sites (`padded.load(...)` works).
#[derive(Debug, Default)]
#[repr(align(64))]
pub struct CachePadded<T> {
    value: T,
}

impl<T> CachePadded<T> {
    /// Wrap `value` on its own cache line.
    pub const fn new(value: T) -> Self {
        Self { value }
    }

    /// Unwrap, discarding the alignment.
    pub fn into_inner(self) -> T {
        self.value
    }
}

impl<T> std::ops::Deref for CachePadded<T> {
    type Target = T;

    fn deref(&self) -> &T {
        &self.value
    }
}

impl<T> std::ops::DerefMut for CachePadded<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.value
    }
}

/// Seqlock-published estimate table: μ̂ per worker plus the aggregate λ̂.
///
/// Single writer (the plane's aggregator), any number of readers. The
/// sequence word and λ̂ sit on their own cache lines: `seq` is re-read by
/// every frontend on every decision, and without padding a publish storing
/// through the adjacent `mu_bits`/`lambda_bits` words would bounce the
/// line holding `seq` across every deciding core.
#[derive(Debug)]
pub struct EstimateTable {
    /// Sequence counter: even = stable, odd = publish in progress.
    seq: CachePadded<AtomicU64>,
    /// f64 bit patterns of μ̂ per worker.
    mu_bits: Box<[AtomicU64]>,
    /// f64 bit pattern of the aggregate λ̂ (tasks/second).
    lambda_bits: CachePadded<AtomicU64>,
}

impl EstimateTable {
    /// Table for `n` workers, initialized to the prior estimate and λ̂ = 0.
    pub fn new(n: usize, prior: f64) -> Self {
        assert!(n > 0, "estimate table over empty cluster");
        debug_assert_eq!(
            std::mem::size_of::<CachePadded<AtomicUsize>>(),
            64,
            "CachePadded must occupy exactly one cache line"
        );
        Self {
            seq: CachePadded::new(AtomicU64::new(0)),
            mu_bits: (0..n).map(|_| AtomicU64::new(prior.to_bits())).collect(),
            lambda_bits: CachePadded::new(AtomicU64::new(0f64.to_bits())),
        }
    }

    /// Number of workers.
    pub fn n(&self) -> usize {
        self.mu_bits.len()
    }

    /// Publish a new estimate vector. Must only be called from one thread
    /// at a time (the aggregator); readers never block.
    pub fn publish(&self, mu_hat: &[f64], lambda_tasks: f64) {
        assert_eq!(mu_hat.len(), self.mu_bits.len(), "estimate vector length mismatch");
        let s = self.seq.fetch_add(1, Ordering::Relaxed); // now odd
        debug_assert!(s % 2 == 0, "concurrent EstimateTable publisher");
        fence(Ordering::Release);
        for (slot, &v) in self.mu_bits.iter().zip(mu_hat) {
            slot.store(v.to_bits(), Ordering::Relaxed);
        }
        self.lambda_bits.store(lambda_tasks.to_bits(), Ordering::Relaxed);
        self.seq.store(s.wrapping_add(2), Ordering::Release);
    }

    /// Current publication epoch (even when stable). One atomic load — the
    /// per-decision staleness probe.
    #[inline]
    pub fn epoch(&self) -> u64 {
        self.seq.load(Ordering::Acquire)
    }

    /// Read a consistent snapshot into `mu_out`; returns `(λ̂, epoch)`.
    /// Spins only while a publish is in flight (microseconds).
    pub fn read(&self, mu_out: &mut [f64]) -> (f64, u64) {
        assert_eq!(mu_out.len(), self.mu_bits.len(), "estimate buffer length mismatch");
        loop {
            let s1 = self.seq.load(Ordering::Acquire);
            if s1 % 2 == 1 {
                std::hint::spin_loop();
                continue;
            }
            for (out, slot) in mu_out.iter_mut().zip(self.mu_bits.iter()) {
                *out = f64::from_bits(slot.load(Ordering::Relaxed));
            }
            let lambda = f64::from_bits(self.lambda_bits.load(Ordering::Relaxed));
            fence(Ordering::Acquire);
            let s2 = self.seq.load(Ordering::Relaxed);
            if s1 == s2 {
                return (lambda, s1);
            }
            std::hint::spin_loop();
        }
    }

    /// Convenience snapshot for reports and tests.
    pub fn snapshot(&self) -> (Vec<f64>, f64) {
        let mut mu = vec![0.0; self.n()];
        let (lambda, _) = self.read(&mut mu);
        (mu, lambda)
    }

    /// Current λ̂ alone — one relaxed atomic load, no seqlock round trip.
    /// Used by the metrics scrape path, where a value torn against μ̂ is
    /// acceptable (it is a gauge, not an invariant).
    pub fn lambda(&self) -> f64 {
        f64::from_bits(self.lambda_bits.load(Ordering::Relaxed))
    }
}

/// A frontend's private cache of the last estimate-table read: the μ̂
/// vector, the O(1) proportional sampler rebuilt from it, the aggregate λ̂,
/// and the epoch the cache corresponds to.
#[derive(Debug, Clone)]
pub struct EstimateCache {
    /// Cached speed estimates.
    pub mu_hat: Vec<f64>,
    /// Alias sampler over `mu_hat` (rebuilt on refresh, never per task).
    pub sampler: AliasTable,
    /// Cached aggregate arrival-rate estimate (tasks/second).
    pub lambda_tasks: f64,
    /// Epoch of the table publication this cache reflects.
    pub epoch: u64,
}

impl EstimateCache {
    /// Cache initialized to the prior (matches a fresh [`EstimateTable`]).
    pub fn new(n: usize, prior: f64) -> Self {
        let mu_hat = vec![prior; n];
        Self { sampler: AliasTable::new(&mu_hat), mu_hat, lambda_tasks: 0.0, epoch: 0 }
    }
}

/// [`ClusterView`] over the plane's shared state: atomic queue-length
/// probes plus a frontend's estimate cache. No locks, no copies — a
/// scheduling decision touches exactly the probed workers.
///
/// When a [`crate::obs::ProbeTrace`] is attached (flight recorder on),
/// each `queue_len` probe is captured as it happens — the recorder sees
/// the workers the policy *actually* probed and the queue lengths it saw,
/// without any change to the policy trait or its RNG draws.
pub struct SharedView<'a> {
    /// Per-worker queue-length probes (shared with the worker threads),
    /// one cache line each.
    pub qlen: &'a [Arc<CachePadded<AtomicUsize>>],
    /// The deciding frontend's estimate cache.
    pub est: &'a EstimateCache,
    /// Optional probe capture for the decision flight recorder.
    pub trace: Option<&'a crate::obs::ProbeTrace>,
}

impl ClusterView for SharedView<'_> {
    fn n(&self) -> usize {
        self.qlen.len()
    }

    #[inline]
    fn queue_len(&self, w: WorkerId) -> usize {
        let q = self.qlen[w].load(Ordering::Relaxed);
        if let Some(trace) = self.trace {
            trace.push(w, q);
        }
        q
    }

    #[inline]
    fn mu_hat(&self, w: WorkerId) -> f64 {
        self.est.mu_hat[w]
    }

    fn lambda_hat(&self) -> f64 {
        self.est.lambda_tasks
    }

    #[inline]
    fn sample(&self, rng: &mut crate::stats::Rng) -> WorkerId {
        self.est.sampler.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;

    #[test]
    fn table_roundtrip() {
        let t = EstimateTable::new(3, 1.0);
        assert_eq!(t.n(), 3);
        let (mu, lambda) = t.snapshot();
        assert_eq!(mu, vec![1.0; 3]);
        assert_eq!(lambda, 0.0);
        let e0 = t.epoch();
        t.publish(&[2.0, 0.5, 1.5], 42.0);
        assert_eq!(t.epoch(), e0 + 2);
        let (mu, lambda) = t.snapshot();
        assert_eq!(mu, vec![2.0, 0.5, 1.5]);
        assert_eq!(lambda, 42.0);
    }

    #[test]
    fn concurrent_readers_never_observe_torn_vectors() {
        // The writer always publishes [k; n] with λ = k; any mix of two
        // publishes would make the elements disagree.
        let n = 16;
        let table = Arc::new(EstimateTable::new(n, 0.0));
        let stop = Arc::new(AtomicBool::new(false));
        let total_reads = Arc::new(AtomicU64::new(0));
        let mut readers = Vec::new();
        for _ in 0..3 {
            let table = table.clone();
            let stop = stop.clone();
            let total_reads = total_reads.clone();
            readers.push(std::thread::spawn(move || {
                let mut buf = vec![0.0; n];
                while !stop.load(Ordering::Relaxed) {
                    let (lambda, epoch) = table.read(&mut buf);
                    assert_eq!(epoch % 2, 0);
                    let first = buf[0];
                    assert!(
                        buf.iter().all(|&v| v == first),
                        "torn read at epoch {epoch}: {buf:?}"
                    );
                    assert_eq!(lambda, first, "λ̂ torn from μ̂ at epoch {epoch}");
                    total_reads.fetch_add(1, Ordering::Relaxed);
                }
            }));
        }
        // Keep publishing until the readers have demonstrably overlapped
        // with plenty of publishes.
        let mut k = 0u64;
        while k < 20_000 || total_reads.load(Ordering::Relaxed) < 100 {
            table.publish(&vec![k as f64; n], k as f64);
            k += 1;
        }
        stop.store(true, Ordering::Relaxed);
        for r in readers {
            r.join().unwrap();
        }
        assert!(total_reads.load(Ordering::Relaxed) >= 100);
    }

    #[test]
    fn epoch_advances_only_on_publish() {
        let t = EstimateTable::new(2, 1.0);
        let e = t.epoch();
        let _ = t.snapshot();
        assert_eq!(t.epoch(), e, "reads must not perturb the epoch");
        t.publish(&[1.0, 1.0], 0.0);
        assert_eq!(t.epoch(), e + 2);
    }

    #[test]
    fn shared_view_reads_probes_and_cache() {
        use crate::stats::Rng;
        let qlen: Vec<Arc<CachePadded<AtomicUsize>>> =
            (0..3).map(|i| Arc::new(CachePadded::new(AtomicUsize::new(i)))).collect();
        let mut est = EstimateCache::new(3, 1.0);
        est.mu_hat = vec![0.0, 0.0, 5.0];
        est.sampler = AliasTable::new(&est.mu_hat);
        est.lambda_tasks = 7.0;
        let trace = crate::obs::ProbeTrace::new();
        let view = SharedView { qlen: &qlen, est: &est, trace: Some(&trace) };
        assert_eq!(view.n(), 3);
        assert_eq!(view.queue_len(2), 2);
        assert_eq!(trace.probes(), vec![(2, 2)], "probe capture missed a read");
        assert_eq!(ClusterView::mu_hat(&view, 2), 5.0);
        assert_eq!(view.lambda_hat(), 7.0);
        let mut rng = Rng::new(9);
        for _ in 0..100 {
            assert_eq!(view.sample(&mut rng), 2, "all weight on worker 2");
        }
        qlen[0].store(9, Ordering::Relaxed);
        assert_eq!(view.queue_len(0), 9, "probe sees live counter updates");
    }

    #[test]
    fn cache_padding_fills_exactly_one_line() {
        assert_eq!(std::mem::size_of::<CachePadded<AtomicUsize>>(), 64);
        assert_eq!(std::mem::align_of::<CachePadded<AtomicUsize>>(), 64);
        assert_eq!(std::mem::size_of::<CachePadded<AtomicU64>>(), 64);
        // Transparent at call sites: atomics work through Deref, and the
        // wrapper never changes the value it holds.
        let p = CachePadded::new(AtomicUsize::new(7));
        assert_eq!(p.fetch_add(1, Ordering::Relaxed), 7);
        assert_eq!(p.into_inner().into_inner(), 8);
    }

    #[test]
    #[should_panic]
    fn mismatched_publish_rejected() {
        let t = EstimateTable::new(3, 1.0);
        t.publish(&[1.0, 2.0], 0.0);
    }
}
