//! Core domain types shared by the simulator, the schedulers, the learner,
//! and the live coordinator.
//!
//! Terminology follows the paper (§2, footnote 2, after Sparrow's
//! convention): a **task** is the minimum compute unit; a **job** contains
//! one or more tasks; the **response time** of a job is the interval between
//! its arrival at the scheduler and the completion of its *last* task.

/// Dense worker identifier, `0..n`.
pub type WorkerId = usize;

/// Monotonic job identifier.
pub type JobId = u64;

/// Monotonic task identifier (unique across jobs).
pub type TaskId = u64;

/// Whether a task is real workload or a learner-injected benchmark
/// ("fake") job. Benchmark tasks are strictly lower priority at the worker
/// (paper §5: node monitors keep two queues).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    /// A task of a user-submitted job; counts toward response-time metrics.
    Real,
    /// A learner benchmark job; excluded from response-time metrics, used
    /// only to produce service-time samples for the performance learner.
    Benchmark,
}

/// Static description of one task before placement.
#[derive(Debug, Clone)]
pub struct TaskSpec {
    /// Service *demand* in seconds of unit-speed work. A worker with speed
    /// `s` serves this task in `demand / s` seconds. (§6.2: demands are
    /// exponential with mean 100 ms; worker `j` sleeps `τ_i / μ_j`.)
    pub demand: f64,
    /// A constrained task must run on this specific backend; the scheduler
    /// has no placement freedom for it (§6.1: TPC-H has ~2k constrained
    /// tasks out of >30k).
    pub constrained_to: Option<WorkerId>,
}

impl TaskSpec {
    /// Unconstrained task with the given demand.
    pub fn new(demand: f64) -> Self {
        Self { demand, constrained_to: None }
    }

    /// Constrained task pinned to `worker`.
    pub fn pinned(demand: f64, worker: WorkerId) -> Self {
        Self { demand, constrained_to: Some(worker) }
    }
}

/// Static description of one job (a set of tasks arriving together).
#[derive(Debug, Clone)]
pub struct JobSpec {
    /// Tasks in this job.
    pub tasks: Vec<TaskSpec>,
}

/// An empty spec, only valid as a reusable buffer for
/// [`crate::workload::Workload::next_job_into`] — every constructor keeps
/// jobs non-empty, and a buffer is refilled before any consumer sees it.
impl Default for JobSpec {
    fn default() -> Self {
        Self { tasks: Vec::new() }
    }
}

impl JobSpec {
    /// Build a job from task specs. Panics on empty jobs.
    pub fn new(tasks: Vec<TaskSpec>) -> Self {
        assert!(!tasks.is_empty(), "job must contain at least one task");
        Self { tasks }
    }

    /// Single-task job with the given demand (the theoretical model of §4).
    pub fn single(demand: f64) -> Self {
        Self::new(vec![TaskSpec::new(demand)])
    }

    /// Number of tasks.
    pub fn len(&self) -> usize {
        self.tasks.len()
    }

    /// False for every constructed job; true only for a [`Default`] buffer
    /// that has not been refilled yet.
    pub fn is_empty(&self) -> bool {
        self.tasks.is_empty()
    }

    /// Number of unconstrained tasks (the ones the policy may place).
    pub fn unconstrained(&self) -> usize {
        self.tasks.iter().filter(|t| t.constrained_to.is_none()).count()
    }

    /// Total service demand of the job.
    pub fn total_demand(&self) -> f64 {
        self.tasks.iter().map(|t| t.demand).sum()
    }
}

/// A concrete task instance in flight.
#[derive(Debug, Clone)]
pub struct Task {
    pub id: TaskId,
    pub job: JobId,
    pub kind: TaskKind,
    pub demand: f64,
    /// Simulation/wall time at which the owning job arrived.
    pub arrival: f64,
}

/// How a policy wants a job's unconstrained tasks placed.
#[derive(Debug, Clone)]
pub enum JobPlacement {
    /// Fast path for single-task jobs (the dominant case in serving
    /// workloads): no allocation per decision.
    Single(WorkerId),
    /// Direct placement: `workers[k]` receives the k-th unconstrained task.
    PerTask(Vec<WorkerId>),
    /// Late binding (Sparrow §5 / [7]): enqueue lightweight reservations at
    /// `workers`; each worker, upon reaching a reservation, pulls the next
    /// unlaunched task of the job from the scheduler. Extra reservations are
    /// cancelled implicitly when the job runs dry.
    Reservations(Vec<WorkerId>),
}

/// Read-only view of cluster state offered to scheduling policies.
///
/// Policies may inspect queue lengths (a probe in the real system) and the
/// current speed estimates. They must not see true speeds unless the
/// experiment grants an oracle (Halo, the "speeds known" settings of §6.2).
///
/// This is a trait so the same policy code runs against two backings:
///
/// * [`LocalView`] — borrowed slices owned by a single-threaded driver (the
///   DES engine, the live coordinator, unit tests);
/// * `plane::SharedView` — lock-free shared state of the sharded scheduling
///   plane: per-worker atomic queue-length probes plus a seqlock-published
///   estimate table, so many frontends schedule concurrently with no lock
///   on the per-decision hot path.
pub trait ClusterView {
    /// Number of workers.
    fn n(&self) -> usize;

    /// Queue length (queued entries + in-service task) of worker `w` —
    /// a probe in the real system.
    fn queue_len(&self, w: WorkerId) -> usize;

    /// Current speed estimate μ̂ of worker `w` published by the learner
    /// (or the true speed in oracle mode).
    fn mu_hat(&self, w: WorkerId) -> f64;

    /// Current arrival-rate estimate λ̂ in tasks/second (the arrival
    /// estimator of §3.3); oracle policies such as Halo use it to compute
    /// routing probabilities.
    fn lambda_hat(&self) -> f64;

    /// Draw one worker from the proportional-sampling multinomial
    /// `p_i = μ̂_i / Σ μ̂` in O(1) (alias table rebuilt on publish).
    fn sample(&self, rng: &mut crate::stats::Rng) -> WorkerId;

    /// Draw two workers (with replacement) — the power-of-two-choices probe.
    fn sample_pair(&self, rng: &mut crate::stats::Rng) -> (WorkerId, WorkerId) {
        (self.sample(rng), self.sample(rng))
    }

    /// Expected waiting time proxy for LL(2): (queue length + 1) / μ̂.
    /// Workers with a zero estimate are treated as infinitely slow.
    fn expected_wait(&self, w: WorkerId) -> f64 {
        let mu = self.mu_hat(w);
        if mu <= 0.0 {
            f64::INFINITY
        } else {
            (self.queue_len(w) + 1) as f64 / mu
        }
    }
}

/// [`ClusterView`] backed by borrowed slices: the single-frontend view used
/// by the DES engine, the live coordinator, and tests.
pub struct LocalView<'a> {
    /// Queue length (queued entries + in-service task) per worker.
    pub queue_len: &'a [usize],
    /// Current speed estimates μ̂ published by the learner (or true speeds
    /// in oracle mode).
    pub mu_hat: &'a [f64],
    /// O(1) proportional sampler over `mu_hat` (rebuilt on publish).
    pub sampler: &'a crate::stats::AliasTable,
    /// Current arrival-rate estimate λ̂ in tasks/second.
    pub lambda_hat: f64,
}

impl ClusterView for LocalView<'_> {
    fn n(&self) -> usize {
        self.queue_len.len()
    }

    #[inline]
    fn queue_len(&self, w: WorkerId) -> usize {
        self.queue_len[w]
    }

    #[inline]
    fn mu_hat(&self, w: WorkerId) -> f64 {
        self.mu_hat[w]
    }

    fn lambda_hat(&self) -> f64 {
        self.lambda_hat
    }

    #[inline]
    fn sample(&self, rng: &mut crate::stats::Rng) -> WorkerId {
        self.sampler.sample(rng)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats::AliasTable;

    #[test]
    fn job_spec_accessors() {
        let j = JobSpec::new(vec![
            TaskSpec::new(0.1),
            TaskSpec::pinned(0.2, 3),
            TaskSpec::new(0.3),
        ]);
        assert_eq!(j.len(), 3);
        assert_eq!(j.unconstrained(), 2);
        assert!((j.total_demand() - 0.6).abs() < 1e-12);
        assert!(!j.is_empty());
    }

    #[test]
    #[should_panic]
    fn empty_job_rejected() {
        JobSpec::new(vec![]);
    }

    #[test]
    fn single_task_job() {
        let j = JobSpec::single(0.5);
        assert_eq!(j.len(), 1);
        assert_eq!(j.tasks[0].constrained_to, None);
    }

    #[test]
    fn expected_wait_uses_estimates() {
        let q = [2usize, 2];
        let mu = [2.0, 0.0];
        let t = AliasTable::new(&mu);
        let view = LocalView { queue_len: &q, mu_hat: &mu, sampler: &t, lambda_hat: 1.0 };
        assert!((view.expected_wait(0) - 1.5).abs() < 1e-12);
        assert!(view.expected_wait(1).is_infinite());
        assert_eq!(view.n(), 2);
        assert_eq!(ClusterView::queue_len(&view, 1), 2);
        assert_eq!(ClusterView::mu_hat(&view, 0), 2.0);
        assert_eq!(ClusterView::lambda_hat(&view), 1.0);
    }
}
