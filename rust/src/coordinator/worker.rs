//! Live worker threads: the node-monitor + executor pair of the paper's
//! implementation (§5), as real OS threads.
//!
//! Each worker owns two inbound queues — real tasks and benchmark tasks,
//! the latter strictly lower priority — and an atomic queue-length counter
//! the scheduler probes without locking. Task execution either sleeps for
//! `demand / speed` (the paper's §6.1 slow-down trick: execute, then hold
//! `(k−1)·T`) or additionally runs the AOT-compiled MLP payload through
//! PJRT, making the serve path a real compute system.
//!
//! A worker's ingress side is split out as [`WorkerClient`] so *multiple*
//! frontends can feed the same worker: the sharded scheduling plane clones
//! one client per shard, and every clone shares the worker's atomic
//! queue-length probe. Enqueue is an mpsc send plus one relaxed
//! `fetch_add` — no locks on the dispatch path.

use crate::plane::CachePadded;
use crate::runtime::PayloadRunner;
use crate::types::TaskKind;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{Receiver, RecvTimeoutError, Sender, TryRecvError};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// A unit of work sent to a live worker.
#[derive(Debug)]
pub struct LiveTask {
    pub job: u64,
    pub kind: TaskKind,
    /// Service demand in unit-speed seconds.
    pub demand: f64,
    /// Wall-clock enqueue instant.
    pub enqueued: Instant,
}

/// Completion report sent back to the coordinator.
#[derive(Debug)]
pub struct Completion {
    pub worker: usize,
    pub job: u64,
    pub kind: TaskKind,
    pub demand: f64,
    /// Measured service duration (seconds).
    pub duration: f64,
    /// Total queueing + service time since enqueue (seconds).
    pub sojourn: f64,
    /// Completion instant.
    pub at: Instant,
}

impl Completion {
    /// Time spent queued before service began (seconds). Sojourn and
    /// duration are measured by different clock reads, so clamp: a
    /// zero-queue task can measure a sojourn a few ns under its duration.
    pub fn queue_wait(&self) -> f64 {
        (self.sojourn - self.duration).max(0.0)
    }
}

/// How workers execute tasks.
#[derive(Debug, Clone)]
pub enum PayloadMode {
    /// Pure sleep tasks (§6.2 synthetic).
    Sleep,
    /// Run the AOT MLP payload through PJRT once per task, then pad with
    /// sleep up to the modelled duration.
    Pjrt { artifacts_dir: String },
}

/// Where a worker reports completions.
///
/// The single-frontend coordinator and the shared-learner plane funnel
/// everything into one channel; a plane with per-shard learners gives every
/// scheduler its own channel, and the node monitor routes each report to
/// the scheduler that dispatched the task — the shard encoded in the job id
/// (§5: each scheduler learns from only the completions it routed).
#[derive(Clone)]
pub enum CompletionSink {
    /// One central consumer.
    Single(Sender<Completion>),
    /// Per-scheduler channels indexed by [`crate::plane::job_shard`].
    Sharded(Vec<Sender<Completion>>),
}

impl CompletionSink {
    /// Per-scheduler sink routed by the shard encoded in the job id.
    pub fn sharded(senders: Vec<Sender<Completion>>) -> Self {
        assert!(!senders.is_empty(), "sharded sink needs at least one channel");
        CompletionSink::Sharded(senders)
    }

    /// Deliver one completion report. A send error just means the consumer
    /// already stopped at shutdown.
    pub fn send(&self, c: Completion) {
        match self {
            CompletionSink::Single(tx) => {
                let _ = tx.send(c);
            }
            CompletionSink::Sharded(txs) => {
                // Out-of-range shards (e.g. the shared-mode benchmark
                // sentinel id) fall back to the last channel.
                let s = crate::plane::job_shard(c.job).min(txs.len() - 1);
                let _ = txs[s].send(c);
            }
        }
    }
}

impl From<Sender<Completion>> for CompletionSink {
    fn from(tx: Sender<Completion>) -> Self {
        CompletionSink::Single(tx)
    }
}

/// Cloneable ingress handle to one worker: the task senders plus the
/// shared atomic probes. Each frontend of the plane owns its own clone;
/// the worker exits once every clone is dropped and its queues drain.
#[derive(Clone)]
pub struct WorkerClient {
    pub real_tx: Sender<LiveTask>,
    pub bench_tx: Sender<LiveTask>,
    /// Real entries queued or in service (the probe the policy sees),
    /// padded to its own cache line so one worker's enqueue/dequeue
    /// traffic never invalidates a neighboring worker's probe.
    pub qlen: Arc<CachePadded<AtomicUsize>>,
    /// Total real tasks this worker has completed (conservation checks).
    pub completed_real: Arc<AtomicU64>,
}

impl WorkerClient {
    /// Enqueue a task, bumping the probe counter for real tasks.
    pub fn enqueue(&self, task: LiveTask) {
        let tx = match task.kind {
            TaskKind::Real => {
                self.qlen.fetch_add(1, Ordering::Relaxed);
                &self.real_tx
            }
            TaskKind::Benchmark => &self.bench_tx,
        };
        // A send error just means the worker already stopped at shutdown.
        let _ = tx.send(task);
    }
}

/// Handle to one spawned worker: its ingress client plus the join handle.
pub struct WorkerHandle {
    pub client: WorkerClient,
    pub join: std::thread::JoinHandle<()>,
}

impl WorkerHandle {
    /// Enqueue through the embedded client.
    pub fn enqueue(&self, task: LiveTask) {
        self.client.enqueue(task)
    }

    /// Drop this handle's senders and join the worker thread (it drains
    /// its queues first). Other outstanding [`WorkerClient`] clones keep
    /// the worker alive until they are dropped too.
    pub fn shutdown(self) {
        let WorkerHandle { client, join } = self;
        drop(client);
        let _ = join.join();
    }
}

/// Spawn a worker thread with the given relative speed.
pub fn spawn(
    id: usize,
    speed: f64,
    mode: PayloadMode,
    completions: impl Into<CompletionSink>,
) -> WorkerHandle {
    spawn_pinned(id, speed, mode, completions, None)
}

/// [`spawn`], optionally pinning the worker thread to a CPU. Pinning is
/// best-effort: a denied `sched_setaffinity` (containers, non-Linux) just
/// leaves the thread unpinned.
pub fn spawn_pinned(
    id: usize,
    speed: f64,
    mode: PayloadMode,
    completions: impl Into<CompletionSink>,
    cpu: Option<usize>,
) -> WorkerHandle {
    let completions = completions.into();
    let (real_tx, real_rx) = std::sync::mpsc::channel::<LiveTask>();
    let (bench_tx, bench_rx) = std::sync::mpsc::channel::<LiveTask>();
    let qlen = Arc::new(CachePadded::new(AtomicUsize::new(0)));
    let completed_real = Arc::new(AtomicU64::new(0));
    let q = qlen.clone();
    let done = completed_real.clone();
    let join = std::thread::Builder::new()
        .name(format!("rosella-worker-{id}"))
        .spawn(move || {
            if let Some(cpu) = cpu {
                let _ = crate::plane::pin_current_thread(cpu);
            }
            worker_loop(id, speed, mode, real_rx, bench_rx, q, done, completions)
        })
        .expect("spawn worker thread");
    WorkerHandle { client: WorkerClient { real_tx, bench_tx, qlen, completed_real }, join }
}

#[allow(clippy::too_many_arguments)]
fn worker_loop(
    id: usize,
    speed: f64,
    mode: PayloadMode,
    real_rx: Receiver<LiveTask>,
    bench_rx: Receiver<LiveTask>,
    qlen: Arc<CachePadded<AtomicUsize>>,
    completed_real: Arc<AtomicU64>,
    completions: CompletionSink,
) {
    // The PJRT client/executable are created inside the worker thread: one
    // compiled payload per executor, mirroring one Spark executor per
    // backend.
    let payload = match &mode {
        PayloadMode::Sleep => None,
        PayloadMode::Pjrt { artifacts_dir } => {
            match PayloadRunner::load(artifacts_dir, 1000 + id as u64) {
                Ok(p) => Some(p),
                Err(e) => {
                    crate::log_warn!("worker {id}: payload load failed ({e}); falling back to sleep");
                    None
                }
            }
        }
    };
    let mut x = vec![0.1f32; crate::runtime::BATCH * crate::runtime::D_IN];

    loop {
        // Priority: drain real tasks first; benchmark tasks only when no
        // real task is waiting (§5 dual queues).
        let task = match real_rx.try_recv() {
            Ok(t) => Some(t),
            Err(TryRecvError::Empty) => match bench_rx.try_recv() {
                Ok(t) => Some(t),
                Err(TryRecvError::Empty) => {
                    // Nothing queued: block briefly on the real queue so
                    // new real tasks start immediately.
                    match real_rx.recv_timeout(Duration::from_millis(1)) {
                        Ok(t) => Some(t),
                        Err(RecvTimeoutError::Timeout) => None,
                        Err(RecvTimeoutError::Disconnected) => return,
                    }
                }
                Err(TryRecvError::Disconnected) => return,
            },
            Err(TryRecvError::Disconnected) => return,
        };
        let Some(task) = task else { continue };

        let start = Instant::now();
        let target = Duration::from_secs_f64(task.demand / speed);
        if let Some(p) = payload.as_ref() {
            // Real compute: run the MLP batch, vary the input slightly so
            // XLA cannot cache-trivialize anything.
            x[0] = (task.job % 97) as f32 * 0.01;
            if let Ok(y) = p.infer(&x) {
                // Fold the output back into the input buffer (keeps the
                // computation live and data-dependent).
                x[1] = y[0] * 1e-3;
            }
        }
        // Paper §6.1: "the worker holds the task (k−1)·T more time" — pad
        // the measured compute up to the modelled service duration.
        let elapsed = start.elapsed();
        if elapsed < target {
            std::thread::sleep(target - elapsed);
        }
        let end = Instant::now();
        if task.kind == TaskKind::Real {
            qlen.fetch_sub(1, Ordering::Relaxed);
            completed_real.fetch_add(1, Ordering::Relaxed);
        }
        completions.send(Completion {
            worker: id,
            job: task.job,
            kind: task.kind,
            demand: task.demand,
            duration: (end - start).as_secs_f64(),
            sojourn: (end - task.enqueued).as_secs_f64(),
            at: end,
        });
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn executes_and_reports_completion() {
        let (tx, rx) = std::sync::mpsc::channel();
        let w = spawn(0, 2.0, PayloadMode::Sleep, tx);
        w.enqueue(LiveTask {
            job: 1,
            kind: TaskKind::Real,
            demand: 0.02,
            enqueued: Instant::now(),
        });
        let c = rx.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(c.worker, 0);
        assert_eq!(c.job, 1);
        // Speed 2.0: duration ≈ demand/2 = 10 ms (sleep granularity adds
        // some slack).
        assert!(c.duration >= 0.009, "duration {}", c.duration);
        assert!(c.duration < 0.05, "duration {}", c.duration);
        assert_eq!(w.client.qlen.load(Ordering::Relaxed), 0);
        assert_eq!(w.client.completed_real.load(Ordering::Relaxed), 1);
        // An immediately-served task has near-zero queue wait, and the
        // decomposition never goes negative on mismatched clock reads.
        assert!(c.queue_wait() >= 0.0);
        assert!(c.queue_wait() < c.sojourn, "wait {} sojourn {}", c.queue_wait(), c.sojourn);
        w.shutdown();
    }

    #[test]
    fn queue_wait_clamps_mismatched_clock_reads() {
        let mk = |sojourn: f64| Completion {
            worker: 0,
            job: 1,
            kind: TaskKind::Real,
            demand: 0.1,
            duration: 0.02,
            sojourn,
            at: Instant::now(),
        };
        assert!((mk(0.05).queue_wait() - 0.03).abs() < 1e-12);
        // Sojourn measured a hair under duration (separate clock reads):
        // clamp to zero rather than report negative queueing.
        assert_eq!(mk(0.0199).queue_wait(), 0.0);
    }

    #[test]
    fn sharded_sink_routes_completions_to_the_dispatching_shard() {
        let (tx0, rx0) = std::sync::mpsc::channel();
        let (tx1, rx1) = std::sync::mpsc::channel();
        let w = spawn(7, 4.0, PayloadMode::Sleep, CompletionSink::sharded(vec![tx0, tx1]));
        w.enqueue(LiveTask {
            job: crate::plane::encode_job(1, 5),
            kind: TaskKind::Real,
            demand: 0.002,
            enqueued: Instant::now(),
        });
        w.enqueue(LiveTask {
            job: crate::plane::encode_job(0, 9),
            kind: TaskKind::Real,
            demand: 0.002,
            enqueued: Instant::now(),
        });
        let c1 = rx1.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(crate::plane::job_shard(c1.job), 1);
        let c0 = rx0.recv_timeout(Duration::from_secs(2)).unwrap();
        assert_eq!(crate::plane::job_shard(c0.job), 0);
        // Nothing crossed channels.
        assert!(rx0.try_recv().is_err());
        assert!(rx1.try_recv().is_err());
        w.shutdown();
    }

    #[test]
    fn real_tasks_preempt_benchmark_queue() {
        let (tx, rx) = std::sync::mpsc::channel();
        let w = spawn(1, 1.0, PayloadMode::Sleep, tx);
        // Queue several benchmarks, then a real task. The real task must
        // not wait behind all benchmarks.
        for j in 0..5 {
            w.enqueue(LiveTask {
                job: 100 + j,
                kind: TaskKind::Benchmark,
                demand: 0.02,
                enqueued: Instant::now(),
            });
        }
        std::thread::sleep(Duration::from_millis(5));
        w.enqueue(LiveTask {
            job: 1,
            kind: TaskKind::Real,
            demand: 0.01,
            enqueued: Instant::now(),
        });
        let mut order = Vec::new();
        for _ in 0..6 {
            let c = rx.recv_timeout(Duration::from_secs(2)).unwrap();
            order.push((c.kind, c.job));
        }
        let real_pos = order.iter().position(|(k, _)| *k == TaskKind::Real).unwrap();
        assert!(real_pos <= 2, "real task served too late: {order:?}");
        w.shutdown();
    }

    #[test]
    fn qlen_tracks_backlog() {
        let (tx, rx) = std::sync::mpsc::channel();
        let w = spawn(2, 1.0, PayloadMode::Sleep, tx);
        for j in 0..4 {
            w.enqueue(LiveTask {
                job: j,
                kind: TaskKind::Real,
                demand: 0.02,
                enqueued: Instant::now(),
            });
        }
        assert!(w.client.qlen.load(Ordering::Relaxed) >= 3);
        for _ in 0..4 {
            rx.recv_timeout(Duration::from_secs(2)).unwrap();
        }
        std::thread::sleep(Duration::from_millis(5));
        assert_eq!(w.client.qlen.load(Ordering::Relaxed), 0);
        assert_eq!(w.client.completed_real.load(Ordering::Relaxed), 4);
        w.shutdown();
    }

    #[test]
    fn cloned_clients_feed_one_worker() {
        // Two "frontends" dispatching through clones of the same client:
        // both see the shared probe and the worker serves everything.
        let (tx, rx) = std::sync::mpsc::channel();
        let w = spawn(3, 4.0, PayloadMode::Sleep, tx);
        let a = w.client.clone();
        let b = w.client.clone();
        let t1 = std::thread::spawn(move || {
            for j in 0..10 {
                a.enqueue(LiveTask {
                    job: j,
                    kind: TaskKind::Real,
                    demand: 0.002,
                    enqueued: Instant::now(),
                });
            }
            drop(a);
        });
        let t2 = std::thread::spawn(move || {
            for j in 10..20 {
                b.enqueue(LiveTask {
                    job: j,
                    kind: TaskKind::Real,
                    demand: 0.002,
                    enqueued: Instant::now(),
                });
            }
            drop(b);
        });
        t1.join().unwrap();
        t2.join().unwrap();
        let mut jobs = Vec::new();
        for _ in 0..20 {
            jobs.push(rx.recv_timeout(Duration::from_secs(2)).unwrap().job);
        }
        jobs.sort_unstable();
        assert_eq!(jobs, (0..20).collect::<Vec<u64>>());
        assert_eq!(w.client.completed_real.load(Ordering::Relaxed), 20);
        w.shutdown();
    }
}
