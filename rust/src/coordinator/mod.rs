//! Live threaded coordinator: Rosella serving real requests on real worker
//! threads, with Python strictly out of the request path.
//!
//! Architecture (paper §5, Figure 7):
//!
//! * the **frontend/scheduler** (this module, main thread) owns the arrival
//!   loop, the arrival estimator, the scheduling policy, and publishes
//!   estimates — all bundled in the [`crate::plane::FrontendCore`] shared
//!   with the sharded scheduling plane, so a plane shard and this
//!   coordinator make identical decisions for identical inputs;
//! * **node monitors + executors** are worker threads
//!   ([`worker`]) with dual priority queues and atomic queue-length probes;
//! * the **performance learner** aggregates completion reports; estimate
//!   publication can run natively or through the AOT Pallas learner
//!   artifact (PJRT), verified equivalent;
//! * the **benchmark dispatcher** injects low-priority fake jobs at rate
//!   `c0(μ̄ − λ̂)`.

pub mod worker;

pub use worker::{Completion, CompletionSink, LiveTask, PayloadMode, WorkerClient, WorkerHandle};

use crate::learner::{FakeJobDispatcher, PerfLearner};
use crate::metrics::ResponseRecorder;
use crate::plane::FrontendCore;
use crate::scheduler::PolicyKind;
use crate::stats::{Exponential, FiveNum, Rng};
use crate::types::{JobSpec, TaskKind};
use std::sync::atomic::AtomicUsize;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Live-serving configuration.
#[derive(Debug, Clone)]
pub struct LiveConfig {
    /// Worker speed multipliers (one thread per entry).
    pub speeds: Vec<f64>,
    /// Scheduling policy.
    pub policy: PolicyKind,
    /// Request arrival rate (jobs/sec, Poisson).
    pub rate: f64,
    /// Wall-clock serving duration (seconds).
    pub duration: f64,
    /// Mean task demand (unit-speed seconds).
    pub mean_demand: f64,
    /// Execution mode.
    pub payload: PayloadMode,
    /// Use the PJRT learner artifact for estimate publication when
    /// available (falls back to native on load failure).
    pub pjrt_learner: bool,
    /// RNG seed.
    pub seed: u64,
    /// Estimate publish interval (seconds).
    pub publish_interval: f64,
}

impl Default for LiveConfig {
    fn default() -> Self {
        Self {
            speeds: vec![1.0, 0.5, 0.25, 2.0],
            policy: PolicyKind::PPoT {
                tie: crate::scheduler::TieRule::Sq2,
                late_binding: false,
            },
            rate: 50.0,
            duration: 5.0,
            mean_demand: 0.02,
            payload: PayloadMode::Sleep,
            pjrt_learner: false,
            seed: 42,
            publish_interval: 0.25,
        }
    }
}

/// Serving report.
#[derive(Debug)]
pub struct LiveReport {
    /// Completed request count.
    pub completed: usize,
    /// Wall-clock duration actually served.
    pub elapsed: f64,
    /// Requests/sec achieved.
    pub throughput: f64,
    /// Response-time five-number summary (seconds).
    pub five: FiveNum,
    /// Mean response time (seconds).
    pub mean: f64,
    /// Benchmark tasks executed.
    pub benchmarks: u64,
    /// Final speed estimates vs configured speeds.
    pub estimates: Vec<(f64, f64)>,
    /// Which learner backend produced the final estimates.
    pub learner_backend: &'static str,
}

impl LiveReport {
    /// Render a human-readable report.
    pub fn render(&self) -> String {
        let mut out = String::new();
        out.push_str(&format!(
            "served {} requests in {:.2}s — {:.1} req/s\n",
            self.completed, self.elapsed, self.throughput
        ));
        out.push_str(&format!(
            "latency ms: mean {:.1} | p5 {:.1} | p50 {:.1} | p95 {:.1}\n",
            self.mean * 1e3,
            self.five.p5 * 1e3,
            self.five.p50 * 1e3,
            self.five.p95 * 1e3
        ));
        out.push_str(&format!(
            "benchmark tasks: {} (learner backend: {})\n",
            self.benchmarks, self.learner_backend
        ));
        out.push_str("worker speed estimates (true → learned):\n");
        for (i, (truth, est)) in self.estimates.iter().enumerate() {
            out.push_str(&format!("  worker {i}: {truth:.2} → {est:.2}\n"));
        }
        out
    }
}

/// Run the live coordinator to completion.
pub fn serve(cfg: LiveConfig) -> Result<LiveReport, String> {
    if cfg.speeds.is_empty() {
        return Err("need at least one worker".into());
    }
    if !(cfg.rate > 0.0 && cfg.duration > 0.0 && cfg.mean_demand > 0.0) {
        return Err("rate, duration, and mean demand must be positive".into());
    }
    let n = cfg.speeds.len();
    let mut rng = Rng::new(cfg.seed);
    let core_seed = rng.next_u64();

    // Spawn the node monitors / executors.
    let (comp_tx, comp_rx) = std::sync::mpsc::channel::<Completion>();
    let workers: Vec<WorkerHandle> = cfg
        .speeds
        .iter()
        .enumerate()
        .map(|(i, &s)| worker::spawn(i, s, cfg.payload.clone(), comp_tx.clone()))
        .collect();
    drop(comp_tx);

    // Learner stack + the frontend decision core (shared with the plane).
    let total_speed: f64 = cfg.speeds.iter().sum();
    let mu_bar = total_speed / cfg.mean_demand; // tasks/sec
    let prior = total_speed / n as f64;
    let mut perf = PerfLearner::new(n, 10.0, cfg.mean_demand, mu_bar, prior, 0.0);
    let dispatcher = FakeJobDispatcher::new(0.1, mu_bar, true);
    let mut core = FrontendCore::new(&cfg.policy, n, prior, cfg.mean_demand, 128, core_seed);
    let mut mu_hat = vec![prior; n];
    let learner_kernel = if cfg.pjrt_learner && n <= crate::runtime::learner_exec::N_WORKERS {
        match crate::runtime::LearnerKernel::load(match &cfg.payload {
            PayloadMode::Pjrt { artifacts_dir } => artifacts_dir,
            PayloadMode::Sleep => "artifacts",
        }) {
            Ok(k) => Some(k),
            Err(e) => {
                crate::log_warn!("learner artifact unavailable ({e}); using native learner");
                None
            }
        }
    } else {
        None
    };
    let learner_backend = if learner_kernel.is_some() { "pjrt" } else { "native" };

    // Serving loop (the frontend).
    let start = Instant::now();
    let gap_dist = Exponential::new(cfg.rate);
    let demand_dist = Exponential::with_mean(cfg.mean_demand);
    let mut next_arrival = start + Duration::from_secs_f64(gap_dist.sample(&mut rng));
    let mut next_publish = start + Duration::from_secs_f64(cfg.publish_interval);
    let mut next_bench = start + Duration::from_secs_f64(0.05);
    let end = start + Duration::from_secs_f64(cfg.duration);
    let mut responses = ResponseRecorder::new(0.0);
    let mut next_job: u64 = 0;
    let mut benchmarks: u64 = 0;
    // Per-worker atomic probes, shared with the worker threads: a decision
    // reads only the workers it probes — no O(n) snapshot per arrival.
    let qlen: Vec<Arc<crate::plane::CachePadded<AtomicUsize>>> =
        workers.iter().map(|w| w.client.qlen.clone()).collect();
    // Reused single-task request spec: no allocation per arrival.
    let mut job = JobSpec::single(cfg.mean_demand);

    loop {
        let now = Instant::now();
        if now >= end {
            break;
        }
        // 1. Admit arrivals that are due.
        while Instant::now() >= next_arrival {
            let t_sched = (next_arrival - start).as_secs_f64();
            core.on_arrival(t_sched, 1);
            let demand = demand_dist.sample(&mut rng).max(1e-4);
            job.tasks[0].demand = demand;
            let target = core.decide_shared(&job, &qlen);
            workers[target].enqueue(LiveTask {
                job: next_job,
                kind: TaskKind::Real,
                demand,
                enqueued: next_arrival.max(start),
            });
            next_job += 1;
            next_arrival += Duration::from_secs_f64(gap_dist.sample(&mut rng));
        }
        // 2. Benchmark dispatch (LEARNER-DISPATCHER).
        while Instant::now() >= next_bench {
            let lam = core.lambda_or(0.0);
            let gap = dispatcher
                .next_gap(lam, &mut rng)
                .unwrap_or(cfg.duration)
                .clamp(1e-3, 1.0);
            let w = dispatcher.pick_worker(n, &mut rng);
            workers[w].enqueue(LiveTask {
                job: u64::MAX,
                kind: TaskKind::Benchmark,
                demand: demand_dist.sample(&mut rng).max(1e-4),
                enqueued: Instant::now(),
            });
            benchmarks += 1;
            next_bench += Duration::from_secs_f64(gap);
        }
        // 3. Publish estimates.
        if Instant::now() >= next_publish {
            let now_s = start.elapsed().as_secs_f64();
            let lambda = core.lambda_or(0.0);
            let params = perf.publish(now_s, lambda);
            if let Some(kernel) = learner_kernel.as_ref() {
                let cold = now_s < params.horizon;
                match kernel.publish(&perf, now_s, &params, cold) {
                    Ok(est) => {
                        for (i, src) in est.iter().enumerate() {
                            // The kernel has no host-side prior; keep the
                            // native estimate for rows it zeroes during
                            // cold start (silent workers).
                            mu_hat[i] =
                                if *src > 0.0 { *src as f64 } else { perf.mu_hat()[i] };
                        }
                    }
                    Err(e) => {
                        crate::log_warn!("pjrt learner failed ({e}); using native");
                        mu_hat.copy_from_slice(perf.mu_hat());
                    }
                }
            } else {
                mu_hat.copy_from_slice(perf.mu_hat());
            }
            core.set_estimates(&mu_hat, lambda);
            next_publish += Duration::from_secs_f64(cfg.publish_interval);
        }
        // 4. Drain completions until the next timer.
        let next_due = next_arrival.min(next_bench).min(next_publish).min(end);
        let timeout = next_due.saturating_duration_since(Instant::now());
        if let Ok(c) = comp_rx.recv_timeout(timeout.min(Duration::from_millis(5))) {
            handle_completion(&mut perf, &mut responses, start, &c);
            while let Ok(c) = comp_rx.try_recv() {
                handle_completion(&mut perf, &mut responses, start, &c);
            }
        }
    }

    // Shutdown: drop senders, join workers, drain stragglers briefly.
    let elapsed = start.elapsed().as_secs_f64();
    for w in workers {
        w.shutdown();
    }
    while let Ok(c) = comp_rx.try_recv() {
        handle_completion(&mut perf, &mut responses, start, &c);
    }

    let estimates: Vec<(f64, f64)> =
        cfg.speeds.iter().zip(core.mu_hat().iter()).map(|(&t, &e)| (t, e)).collect();
    Ok(LiveReport {
        completed: responses.count(),
        elapsed,
        throughput: responses.count() as f64 / elapsed,
        five: responses.five_num(),
        mean: responses.mean(),
        benchmarks,
        estimates,
        learner_backend,
    })
}

fn handle_completion(
    perf: &mut PerfLearner,
    responses: &mut ResponseRecorder,
    start: Instant,
    c: &Completion,
) {
    let now_s = (c.at - start).as_secs_f64();
    perf.on_completion(c.worker, now_s, c.duration.max(1e-6), c.demand);
    if c.kind == TaskKind::Real {
        responses.record(now_s - c.sojourn, now_s);
    }
}

/// CLI adapter for `rosella serve`.
pub fn serve_cli(p: &crate::cli::Parsed) -> Result<String, String> {
    let workers: usize = p.parse_as("workers")?.unwrap_or(4);
    let speeds = match p.get("speeds") {
        Some(s) => {
            let profile = crate::cluster::SpeedProfile::parse(s)?;
            profile.speeds(&mut Rng::new(1))
        }
        None => {
            let base = [1.0, 0.5, 0.25, 2.0];
            (0..workers).map(|i| base[i % base.len()]).collect()
        }
    };
    let policy = crate::scheduler::PolicyKind::parse(p.get("policy").unwrap_or("ppot"))?;
    let rate: f64 = p.parse_as("rate")?.unwrap_or(50.0);
    let duration: f64 = p.parse_as("duration")?.unwrap_or(10.0);
    let artifacts = p.get("artifacts").unwrap_or("artifacts").to_string();
    let payload = if p.flag("sleep-payload") || !crate::runtime::artifacts_present(&artifacts) {
        PayloadMode::Sleep
    } else {
        PayloadMode::Pjrt { artifacts_dir: artifacts }
    };
    let pjrt_learner = matches!(payload, PayloadMode::Pjrt { .. });
    let cfg = LiveConfig {
        speeds,
        policy,
        rate,
        duration,
        payload,
        pjrt_learner,
        ..LiveConfig::default()
    };
    serve(cfg).map(|r| r.render())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn live_serving_sleep_mode() {
        let cfg = LiveConfig {
            speeds: vec![1.0, 0.5],
            rate: 100.0,
            duration: 1.5,
            mean_demand: 0.005,
            ..LiveConfig::default()
        };
        let r = serve(cfg).unwrap();
        assert!(r.completed > 50, "completed {}", r.completed);
        assert!(r.mean > 0.0 && r.mean < 0.5, "mean {}", r.mean);
        assert!(r.benchmarks > 0);
        assert_eq!(r.estimates.len(), 2);
    }

    #[test]
    fn learner_estimates_converge_live() {
        // Very distinct speeds; enough traffic for the learner to see both.
        let cfg = LiveConfig {
            speeds: vec![2.0, 0.4],
            rate: 150.0,
            duration: 2.5,
            mean_demand: 0.004,
            publish_interval: 0.1,
            ..LiveConfig::default()
        };
        let r = serve(cfg).unwrap();
        let (t0, e0) = r.estimates[0];
        let (t1, e1) = r.estimates[1];
        // Ordering must be learned even if magnitudes are biased by (1−ε).
        assert!(e0 > e1, "estimates not ordered: {e0} vs {e1} (true {t0} vs {t1})");
    }

    #[test]
    fn uniform_policy_live_smoke() {
        let cfg = LiveConfig {
            policy: PolicyKind::Uniform,
            speeds: vec![1.0; 3],
            rate: 60.0,
            duration: 1.0,
            mean_demand: 0.004,
            ..LiveConfig::default()
        };
        let r = serve(cfg).unwrap();
        assert!(r.completed > 20);
    }

    #[test]
    fn serve_rejects_bad_configs() {
        let mut cfg = LiveConfig { speeds: vec![], ..LiveConfig::default() };
        assert!(serve(cfg.clone()).is_err());
        cfg.speeds = vec![1.0];
        cfg.rate = 0.0;
        assert!(serve(cfg).is_err());
    }
}
