//! Rosella CLI — the leader entrypoint.
//!
//! Subcommands:
//!
//! * `experiment <name>` — regenerate a paper figure (fig8..fig13, theory,
//!   all);
//! * `simulate` — run one simulation from flags or a JSON config;
//! * `serve` — run the live threaded coordinator with the PJRT payload;
//! * `plane` — run the sharded scheduling plane stress harness (sweeps the
//!   frontend count, reports decisions/sec and latency percentiles);
//! * `hotpath` — measure per-decision latency, alias-rebuild cost, and
//!   simulator/plane throughput per cluster size (`BENCH_hotpath.json`);
//! * `list` — show available experiments, policies, speed profiles.

use rosella::cli::CmdSpec;
use rosella::config;
use rosella::experiments::{self, Scale};
use rosella::simulator::{run as sim_run, SimConfig};

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match args.first().map(|s| s.as_str()) {
        Some("experiment") => cmd_experiment(&args[1..]),
        Some("simulate") => cmd_simulate(&args[1..]),
        Some("serve") => cmd_serve(&args[1..]),
        Some("plane") => cmd_plane(&args[1..]),
        Some("frontend") => cmd_frontend(&args[1..]),
        Some("hotpath") => cmd_hotpath(&args[1..]),
        Some("list") => cmd_list(),
        Some("--help") | Some("-h") | None => {
            print_usage();
            0
        }
        Some(other) => {
            eprintln!("unknown subcommand '{other}'\n");
            print_usage();
            2
        }
    };
    std::process::exit(code);
}

fn print_usage() {
    println!(
        "rosella — self-driving distributed scheduler (paper reproduction)\n\n\
         usage: rosella <subcommand> [options]\n\n\
         subcommands:\n\
         \x20 experiment <name>   regenerate a paper figure (fig8..fig13, theory, all)\n\
         \x20 simulate            run one simulation (flags or --config file.json)\n\
         \x20 serve               run the live coordinator (PJRT payload workers)\n\
         \x20 plane               sharded-plane stress harness (multi-frontend dispatch);\n\
         \x20                     with --listen ADDR: host the cross-process worker pool\n\
         \x20 frontend            remote scheduler process (--connect ADDR --shard i/k)\n\
         \x20 hotpath             hot-path benchmarks per cluster size (BENCH_hotpath.json)\n\
         \x20 list                list experiments, policies, profiles\n"
    );
}

fn cmd_experiment(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("experiment", "regenerate a paper figure")
        .pos("name", "fig8..fig13 | theory | ablation | multisched | all")
        .opt("json", None, "write machine-readable results (multisched only)")
        .flag("quick", "scaled-down run (~10x shorter horizons)");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let name = match p.pos(0) {
        Some(n) => n.to_string(),
        None => {
            eprintln!("{}", spec.help());
            return 2;
        }
    };
    let scale = if p.flag("quick") { Scale::Quick } else { Scale::Full };
    match experiments::run_by_name_with(&name, scale, p.get("json")) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("{e}");
            2
        }
    }
}

fn cmd_simulate(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("simulate", "run one simulation")
        .opt("config", None, "JSON config file (flags override)")
        .opt("seed", None, "rng seed")
        .opt("duration", None, "simulated seconds")
        .opt("warmup", None, "warmup seconds excluded from metrics")
        .opt("speeds", None, "speed profile (s1|s2|tpch:<n>|zipf:<n>:<e>|a,b,c)")
        .opt("volatility", None, "static | permute:<s> | drift:<s>:<sigma>")
        .opt("workload", None, "synthetic | tpch:q3 | tpch:q6")
        .opt("load", None, "target load ratio")
        .opt("policy", None, "uniform|pot|pss|ppot|ppot-ll2|rosella|sparrow|bandit:<eta>|halo")
        .opt("schedulers", None, "logical scheduler count k (§5 per-scheduler learners)")
        .opt("sync-interval", None, "estimate-sync interval in sim-secs (0 = every publish)")
        .opt("sync-policy", None, "estimate-sync strategy: periodic | adaptive | gossip")
        .opt("sync-threshold", None, "adaptive sync: relative-error divergence trigger")
        .opt("timeline-interval", None, "sample a telemetry timeline every N sim-secs")
        .opt("timeline-json", None, "write the sampled timeline as JSON to this path")
        .flag("oracle", "give the policy true speeds (disables learning)")
        .flag("no-fake-jobs", "disable the benchmark-job dispatcher");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    let mut cfg: SimConfig = match p.get("config") {
        Some(path) => match config::sim_config_from_file(path) {
            Ok(c) => c,
            Err(e) => {
                eprintln!("{e}");
                return 2;
            }
        },
        None => SimConfig::synthetic_default(),
    };
    if let Err(e) = apply_overrides(&mut cfg, &p) {
        eprintln!("{e}");
        return 2;
    }
    if let Err(e) = config::validate(&cfg) {
        eprintln!("{e}");
        return 2;
    }
    let result = sim_run(cfg);
    let s = result.responses.summary();
    println!("policy         : {}", result.policy);
    println!("jobs completed : {}", s.count);
    println!("mean response  : {:.1} ms", s.mean * 1e3);
    println!(
        "percentiles ms : p5 {:.1} | p25 {:.1} | p50 {:.1} | p75 {:.1} | p95 {:.1}",
        s.five.p5 * 1e3,
        s.five.p25 * 1e3,
        s.five.p50 * 1e3,
        s.five.p75 * 1e3,
        s.five.p95 * 1e3
    );
    println!("utilization    : {:.3}", result.utilization);
    println!("benchmark frac : {:.4}", result.benchmark_fraction());
    println!("backlog (jobs) : {}", result.incomplete_jobs);
    if !result.timeline.is_empty() {
        println!("timeline points: {}", result.timeline.len());
    }
    if let Some(path) = p.get("timeline-json") {
        let json = rosella::simulator::timeline_json(&result.timeline);
        if let Err(e) = std::fs::write(path, config::to_string(&json)) {
            eprintln!("write {path}: {e}");
            return 1;
        }
        println!("timeline json  : {path}");
    }
    0
}

fn apply_overrides(cfg: &mut SimConfig, p: &rosella::cli::Parsed) -> Result<(), String> {
    if let Some(v) = p.parse_as::<u64>("seed")? {
        cfg.seed = v;
    }
    if let Some(v) = p.parse_as::<f64>("duration")? {
        cfg.duration = v;
    }
    if let Some(v) = p.parse_as::<f64>("warmup")? {
        cfg.warmup = v;
    }
    if let Some(v) = p.get("speeds") {
        cfg.speeds = rosella::cluster::SpeedProfile::parse(v)?;
    }
    if let Some(v) = p.get("volatility") {
        cfg.volatility = rosella::cluster::Volatility::parse(v)?;
    }
    if let Some(v) = p.get("workload") {
        cfg.workload = rosella::workload::WorkloadKind::parse(v)?;
    }
    if let Some(v) = p.parse_as::<f64>("load")? {
        cfg.load = v;
    }
    if let Some(v) = p.get("policy") {
        cfg.policy = rosella::scheduler::PolicyKind::parse(v)?;
    }
    if p.flag("oracle") {
        cfg.learner = rosella::learner::LearnerConfig::oracle();
    }
    if p.flag("no-fake-jobs") {
        cfg.learner.fake_jobs = false;
    }
    if let Some(v) = p.parse_as::<usize>("schedulers")? {
        cfg.learner.schedulers = v;
    }
    if let Some(v) = p.parse_as::<f64>("sync-interval")? {
        cfg.learner.sync_interval = v;
    }
    if let Some(v) = p.get("sync-policy") {
        cfg.learner.sync.kind = rosella::learner::SyncKind::parse(v)?;
    }
    if let Some(v) = p.parse_as::<f64>("sync-threshold")? {
        cfg.learner.sync.threshold = v;
    }
    if let Some(v) = p.parse_as::<f64>("timeline-interval")? {
        cfg.timeline = Some(v);
    }
    Ok(())
}

fn cmd_serve(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("serve", "run the live threaded coordinator")
        .opt("workers", Some("4"), "number of worker threads")
        .opt("speeds", None, "speed profile (defaults to 1.0,0.5,0.25,2.0)")
        .opt("policy", Some("ppot"), "scheduling policy")
        .opt("rate", Some("50"), "request arrival rate (jobs/sec)")
        .opt("duration", Some("10"), "wall-clock seconds to serve")
        .opt("artifacts", Some("artifacts"), "AOT artifact directory")
        .flag("sleep-payload", "use sleep tasks instead of the PJRT payload");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match rosella::coordinator::serve_cli(&p) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("serve failed: {e}");
            1
        }
    }
}

fn cmd_plane(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("plane", "run the sharded scheduling plane stress harness")
        .opt(
            "frontends",
            // No CmdSpec default: the in-process sweep applies "1,2,4"
            // itself, and the --listen server must see only an explicit
            // single count (or its net-config / built-in default of 2).
            None,
            "frontend counts to sweep [default: 1,2,4]; with --listen: the remote scheduler count",
        )
        .opt("workers", Some("8"), "number of worker threads")
        .opt("speeds", None, "speed profile (defaults to a 2.0..0.25 mix)")
        .opt("policy", Some("ppot"), "scheduling policy")
        .opt("rate", Some("400"), "aggregate arrival rate (jobs/sec)")
        .opt("duration", Some("3"), "wall-clock seconds per frontend count")
        .opt("demand", Some("0.01"), "mean task demand (unit-speed seconds)")
        .opt("batch", Some("64"), "arrival ingestion batch size per shard")
        .opt("seed", Some("42"), "rng seed")
        .opt("learners", Some("shared"), "learner ownership: shared | per-shard (§5)")
        .opt("sync-interval", Some("0.2"), "per-shard estimate-sync consensus interval (s)")
        .opt("sync-policy", Some("periodic"), "consensus strategy: periodic | adaptive | gossip")
        .opt("sync-threshold", None, "adaptive sync: relative-error divergence trigger")
        .opt("json", None, "write machine-readable results (e.g. BENCH_plane.json)")
        .opt("listen", None, "host the cross-process pool server on this host:port")
        .opt("net-batch", None, "submit-coalescing batch size B handed to frontends [default: 64]")
        .opt("net-flush-us", None, "submit-coalescing flush deadline D in µs [default: 200]")
        .opt("net-config", None, "JSON file with a `net` block (overrides net flags)")
        .opt("metrics-listen", None, "serve Prometheus /metrics on this host:port for the run")
        .opt("flight-record", None, "dump the decision flight recorder as JSONL to this path")
        .opt("pin", Some("none"), "thread pinning: none | cores | sockets (NUMA-aware placement)")
        .flag("decide-only", "measure raw decision throughput without dispatching")
        .flag("no-fake-jobs", "disable the benchmark-job dispatcher");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    // --listen (or a net-config file) selects the cross-process pool
    // server; otherwise this is the in-process sweep harness.
    let result = if p.get("listen").is_some() || p.get("net-config").is_some() {
        rosella::net::server_cli(&p)
    } else {
        rosella::plane::plane_cli(&p)
    };
    match result {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("plane failed: {e}");
            1
        }
    }
}

fn cmd_frontend(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("frontend", "run one remote scheduler frontend")
        .opt("connect", None, "pool server address (host:port)")
        .opt("shard", None, "this scheduler's shard spec i/k (e.g. 0/2)")
        .opt("connect-timeout", None, "seconds to keep retrying the connect [default: 15]")
        .opt("net-batch", None, "override the server's submit-coalescing batch size B")
        .opt("net-flush-us", None, "override the server's flush deadline D in µs")
        .opt("config", None, "JSON file with a `net` block (overrides flags)")
        .opt("flight-record", None, "dump this frontend's placement flight record (JSONL)")
        .opt("pin", None, "pin this frontend's decision thread: none | cores | sockets");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match rosella::net::frontend_cli(&p) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("frontend failed: {e}");
            1
        }
    }
}

fn cmd_hotpath(rest: &[String]) -> i32 {
    let spec = CmdSpec::new("hotpath", "measure the scheduling hot path per cluster size")
        .opt("sizes", Some("30,256"), "comma-separated cluster sizes")
        .opt("frontends", Some("1,2,4"), "comma-separated plane frontend counts")
        .opt("workers", Some("8"), "plane worker thread count")
        .opt("learners", Some("shared"), "plane learner ownership: shared | per-shard")
        .opt("reps", None, "decision-bench repetitions per run (1M; 50k with --quick)")
        .opt("runs", Some("3"), "measured runs (best-of)")
        .opt("sim-duration", None, "simulated seconds per sim point (60; 5 with --quick)")
        .opt("plane-decisions", None, "decision budget per shard (500k; 20k with --quick)")
        .opt("json", None, "write machine-readable results (e.g. BENCH_hotpath.json)")
        .flag("quick", "scaled-down run for CI smoke")
        .flag("no-plane", "skip the plane throughput sweep");
    let p = match spec.parse(rest) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("{e}");
            return 2;
        }
    };
    match rosella::hotpath::hotpath_cli(&p) {
        Ok(report) => {
            println!("{report}");
            0
        }
        Err(e) => {
            eprintln!("hotpath failed: {e}");
            1
        }
    }
}

fn cmd_list() -> i32 {
    println!("experiments : {}", experiments::ALL.join(", "));
    println!(
        "policies    : uniform, pot, pot:<d>, pss, ppot, ppot-ll2, rosella, sparrow, bandit:<eta>, halo"
    );
    println!("speeds      : s1, s2, example1, homogeneous:<n>:<s>, tpch:<n>, zipf:<n>:<exp>, a,b,c");
    println!("volatility  : static, permute:<secs>, drift:<secs>:<sigma>");
    println!("workloads   : synthetic, tpch:q3, tpch:q6");
    0
}
