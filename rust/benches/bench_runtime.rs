//! Runtime benchmarks: PJRT artifact execution latency (the live worker's
//! per-task compute) and the learner-kernel publish cost, plus the live
//! coordinator's end-to-end serving throughput.
//!
//! Skips PJRT sections when `make artifacts` has not been run.

use rosella::coordinator::{serve, LiveConfig, PayloadMode};
use rosella::learner::PerfLearner;
use rosella::runtime::{LearnerKernel, PayloadRunner};
use rosella::scheduler::PolicyKind;
use std::time::Instant;

fn bench(name: &str, reps: u64, mut f: impl FnMut()) {
    f(); // warmup
    let start = Instant::now();
    for _ in 0..reps {
        f();
    }
    let per = start.elapsed().as_secs_f64() / reps as f64;
    println!("{name:<44} {:>10.1} us/op  {:>10.0} ops/s", per * 1e6, 1.0 / per);
}

fn main() {
    println!("== bench_runtime ==");
    let dir = std::env::var("ROSELLA_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if rosella::runtime::artifacts_present(&dir) {
        // Payload inference latency.
        let runner = PayloadRunner::load(&dir, 7).expect("load payload");
        let x = vec![0.25f32; rosella::runtime::BATCH * rosella::runtime::D_IN];
        bench("payload infer (8x128 MLP batch, PJRT)", 2000, || {
            std::hint::black_box(runner.infer(&x).unwrap());
        });
        bench("payload infer (native rust reference)", 2000, || {
            std::hint::black_box(runner.infer_native(&x));
        });

        // Learner kernel publish.
        let kernel = LearnerKernel::load(&dir).expect("load learner");
        let mut learner = PerfLearner::new(16, 10.0, 0.1, 160.0, 1.0, 0.0);
        let mut t = 0.0;
        for k in 0..2000 {
            t += 0.01;
            learner.on_completion(k % 16, t, 0.05 + (k % 7) as f64 * 0.01, 0.1);
        }
        let params = learner.publish(t, 100.0);
        bench("learner publish (native, n=16)", 20_000, || {
            std::hint::black_box(learner.publish(t, 100.0));
        });
        bench("learner publish (PJRT kernel, n=16)", 500, || {
            std::hint::black_box(kernel.publish(&learner, t, &params, false).unwrap());
        });
    } else {
        println!("(artifacts missing — run `make artifacts` for PJRT benches)");
    }

    // Live coordinator end-to-end throughput (sleep payload: isolates the
    // coordination overhead from compute).
    println!("-- live coordinator (4 workers, 3 s serve) --");
    for rate in [200.0, 800.0] {
        let cfg = LiveConfig {
            speeds: vec![1.0, 1.0, 0.5, 2.0],
            policy: PolicyKind::parse("ppot").unwrap(),
            rate,
            duration: 3.0,
            mean_demand: 0.002,
            payload: PayloadMode::Sleep,
            pjrt_learner: false,
            seed: 9,
            publish_interval: 0.25,
        };
        match serve(cfg) {
            Ok(r) => println!(
                "offered {rate:>6.0} req/s -> served {:>6.0} req/s, p50 {:>7.2} ms, p95 {:>7.2} ms",
                r.throughput,
                r.five.p50 * 1e3,
                r.five.p95 * 1e3
            ),
            Err(e) => eprintln!("serve failed: {e}"),
        }
    }
}
