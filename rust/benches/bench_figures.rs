//! End-to-end figure benchmarks: regenerate every table/figure of the
//! paper's evaluation section and report wall-clock cost per figure.
//!
//! `cargo bench --bench bench_figures` runs all figures at Quick scale;
//! pass a figure name (and optionally `--full`) to run one at full scale:
//! `cargo bench --bench bench_figures -- fig9 --full`.

use rosella::experiments::{run_by_name, Scale, ALL};
use std::time::Instant;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| a != "--bench").collect();
    let full = args.iter().any(|a| a == "--full");
    let wanted: Vec<&str> = args
        .iter()
        .filter(|a| !a.starts_with("--"))
        .map(|s| s.as_str())
        .collect();
    let scale = if full { Scale::Full } else { Scale::Quick };
    let names: Vec<&str> = if wanted.is_empty() {
        ALL.iter().copied().filter(|&n| n != "all").collect()
    } else {
        wanted
    };
    println!("== bench_figures (scale: {scale:?}) ==");
    for name in names {
        let start = Instant::now();
        match run_by_name(name, scale) {
            Ok(report) => {
                let secs = start.elapsed().as_secs_f64();
                println!("\n### {name} ({secs:.2}s wall) ###");
                println!("{report}");
            }
            Err(e) => {
                eprintln!("{name}: {e}");
                std::process::exit(2);
            }
        }
    }
}
