//! Sharded-plane throughput benchmark: aggregate scheduling decisions/sec
//! as the frontend count grows over a fixed shared worker pool.
//!
//! The paper's contrast with centralized learned schedulers (Decima et al.)
//! is exactly this regime: Rosella frontends coordinate only through atomic
//! queue probes and a seqlock-published estimate table, so decision
//! throughput should scale near-linearly with the frontend count until the
//! machine runs out of cores.
//!
//! `cargo bench --bench bench_plane` — decide-only sweep (raw scheduling
//! throughput) followed by an execute-mode latency snapshot.

use rosella::learner::SyncPolicyConfig;
use rosella::plane::{run_plane, DispatchMode, LearnerMode, PlaneConfig};
use rosella::scheduler::{PolicyKind, TieRule};

fn decide_only_sweep() {
    println!("-- decide-only: aggregate scheduling throughput (16 workers) --");
    let base = PlaneConfig {
        speeds: (0..16).map(|i| 0.25 + (i % 8) as f64 * 0.25).collect(),
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        rate: 10_000.0,
        duration: 1.5,
        mean_demand: 0.01,
        batch: 256,
        mode: DispatchMode::DecideOnly,
        fake_jobs: false,
        ..PlaneConfig::default()
    };
    let mut base_rate = 0.0;
    for frontends in [1usize, 2, 4, 8] {
        let cfg = PlaneConfig { frontends, ..base.clone() };
        match run_plane(cfg) {
            Ok(r) => {
                if frontends == 1 {
                    base_rate = r.decisions_per_sec.max(1.0);
                }
                println!(
                    "frontends {frontends:>2}: {:>12.0} decisions/s  (speedup {:>5.2}x)",
                    r.decisions_per_sec,
                    r.decisions_per_sec / base_rate
                );
                println!("              per shard: {:?}", r.per_shard_decisions);
            }
            Err(e) => {
                eprintln!("plane run failed: {e}");
                std::process::exit(2);
            }
        }
    }
}

fn execute_latency() {
    println!("-- execute: paced dispatch latency over the shared pool --");
    for frontends in [1usize, 4] {
        let cfg = PlaneConfig {
            frontends,
            rate: 800.0,
            duration: 2.0,
            mean_demand: 0.004,
            ..PlaneConfig::default()
        };
        match run_plane(cfg) {
            Ok(r) => {
                let five = r.responses.five_num();
                println!(
                    "frontends {frontends}: dispatched {:>5}, completed {:>5}, \
                     p50 {:>6.2} ms, p95 {:>6.2} ms",
                    r.dispatched,
                    r.completed,
                    five.p50 * 1e3,
                    five.p95 * 1e3
                );
            }
            Err(e) => {
                eprintln!("plane run failed: {e}");
                std::process::exit(2);
            }
        }
    }
}

fn learner_ownership_comparison() {
    println!("-- learner ownership: shared aggregator vs per-shard + estimate sync --");
    for learners in [LearnerMode::Shared, LearnerMode::PerShard] {
        let cfg = PlaneConfig {
            frontends: 4,
            rate: 800.0,
            duration: 2.0,
            mean_demand: 0.004,
            publish_interval: 0.1,
            learners,
            sync_interval: 0.2,
            ..PlaneConfig::default()
        };
        match run_plane(cfg) {
            Ok(r) => {
                let five = r.responses.five_num();
                println!(
                    "{:<9}: {:>8.0} decisions/s, completed {:>5}, benchmarks {:>4}, \
                     p50 {:>6.2} ms, p95 {:>6.2} ms, sync epochs {}",
                    learners.name(),
                    r.decisions_per_sec,
                    r.completed,
                    r.benchmarks,
                    five.p50 * 1e3,
                    five.p95 * 1e3,
                    r.sync_epochs
                );
            }
            Err(e) => {
                eprintln!("plane run failed: {e}");
                std::process::exit(2);
            }
        }
    }
}

fn sync_policy_comparison() {
    println!("-- sync policies: consensus strategy under per-shard learners --");
    let cells: [(&str, SyncPolicyConfig); 3] = [
        ("periodic", SyncPolicyConfig::periodic()),
        ("adaptive", SyncPolicyConfig::adaptive(0.1)),
        ("gossip", SyncPolicyConfig::gossip()),
    ];
    for (name, sync_policy) in cells {
        let cfg = PlaneConfig {
            frontends: 4,
            rate: 800.0,
            duration: 2.0,
            mean_demand: 0.004,
            publish_interval: 0.1,
            learners: LearnerMode::PerShard,
            sync_interval: 0.2,
            sync_policy,
            ..PlaneConfig::default()
        };
        match run_plane(cfg) {
            Ok(r) => {
                let five = r.responses.five_num();
                println!(
                    "{:<9}: completed {:>5}, p50 {:>6.2} ms, p95 {:>6.2} ms, \
                     sync epochs {:>3}, merges {:>3}",
                    name,
                    r.completed,
                    five.p50 * 1e3,
                    five.p95 * 1e3,
                    r.sync_epochs,
                    r.sync_merges
                );
            }
            Err(e) => {
                eprintln!("plane run failed: {e}");
                std::process::exit(2);
            }
        }
    }
    println!("(merges < epochs under adaptive = coordination saved; gossip pays");
    println!(" ⌊k/2⌋ pair merges per round instead of one all-to-all epoch)");
}

fn main() {
    println!("== bench_plane ==");
    decide_only_sweep();
    execute_latency();
    learner_ownership_comparison();
    sync_policy_comparison();
}
