//! Hot-path microbenchmarks (criterion is unavailable offline; this is a
//! self-contained harness with warmup, repetition, and best-of-runs
//! reporting).
//!
//! Targets the paper's throughput claim: schedulers must sustain
//! "millions of tasks per second". The scheduling decision — two alias
//! draws + a queue-length comparison — is the per-task cost; the simulator
//! event loop bounds experiment turnaround.

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::learner::LearnerConfig;
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::stats::{AliasTable, Rng};
use rosella::types::{JobPlacement, JobSpec, LocalView};
use rosella::workload::WorkloadKind;
use std::time::Instant;

/// Run `f` for `reps` repetitions, `runs` times; print & return the best
/// run's nanoseconds per repetition.
fn bench(name: &str, reps: u64, runs: usize, mut f: impl FnMut(u64)) -> f64 {
    f(reps / 10 + 1); // warmup
    let mut best = f64::INFINITY;
    for _ in 0..runs {
        let start = Instant::now();
        f(reps);
        let elapsed = start.elapsed().as_nanos() as f64;
        best = best.min(elapsed / reps as f64);
    }
    let per_sec = 1e9 / best;
    println!("{name:<44} {best:>10.1} ns/op  {per_sec:>14.0} ops/s");
    best
}

fn scheduling_decision_benches() {
    println!("-- scheduling decision latency (n = 30 workers) --");
    let n = 30;
    let mut rng = Rng::new(1);
    let speeds: Vec<f64> = (0..n).map(|i| 0.1 + (i % 9) as f64 * 0.1).collect();
    let qlen: Vec<usize> = (0..n).map(|i| i % 7).collect();
    let table = AliasTable::new(&speeds);
    let job = JobSpec::single(0.1);

    let mut run_policy = |name: &str, kind: PolicyKind| {
        let mut policy = kind.build(n);
        policy.on_estimates(&speeds, 100.0);
        let view = LocalView {
            queue_len: &qlen,
            mu_hat: &speeds,
            sampler: &table,
            lambda_hat: 100.0,
        };
        let mut sink = 0usize;
        bench(name, 2_000_000, 3, |reps| {
            for _ in 0..reps {
                if let JobPlacement::Single(w0) = policy.schedule_job(&job, &view, &mut rng) {
                    sink ^= w0;
                }
            }
        });
        std::hint::black_box(sink);
    };
    run_policy("uniform", PolicyKind::Uniform);
    run_policy("pot(2)", PolicyKind::PoT { d: 2 });
    run_policy("pss (alias sample)", PolicyKind::Pss);
    run_policy("ppot-sq2 (rosella)", PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false });
    run_policy("ppot-ll2", PolicyKind::PPoT { tie: TieRule::Ll2, late_binding: false });
    run_policy("halo", PolicyKind::Halo);

    println!("-- estimate publish (alias rebuild, n = 30) --");
    bench("alias table rebuild", 200_000, 3, |reps| {
        for _ in 0..reps {
            std::hint::black_box(AliasTable::new(&speeds));
        }
    });
}

fn simulator_throughput_bench() {
    println!("-- simulator event throughput --");
    for &n in &[15usize, 120] {
        let cfg = SimConfig {
            seed: 3,
            duration: 60.0,
            warmup: 0.0,
            speeds: SpeedProfile::Homogeneous { n, speed: 1.0 },
            volatility: Volatility::Static,
            workload: WorkloadKind::Synthetic,
            load: 0.8,
            policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
            learner: LearnerConfig::oracle(),
            queue_sample: None,
        };
        let start = Instant::now();
        let r = run(cfg);
        let elapsed = start.elapsed().as_secs_f64();
        // Each completed task ≈ 2 events (arrival + completion).
        let events = (r.completed_real * 2) as f64;
        println!(
            "sim n={n:<4} {:>10.0} tasks, {:>12.0} events/s wall",
            r.completed_real as f64,
            events / elapsed
        );
    }
    // With the learning stack enabled (publishes + benchmark jobs).
    let cfg = SimConfig {
        seed: 3,
        duration: 60.0,
        warmup: 0.0,
        speeds: SpeedProfile::S1,
        volatility: Volatility::Permute { period: 30.0 },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::default(),
        queue_sample: None,
    };
    let start = Instant::now();
    let r = run(cfg);
    let elapsed = start.elapsed().as_secs_f64();
    println!(
        "sim full learning stack: {:>8.0} tasks in {elapsed:.3}s wall ({:.0} tasks/s)",
        (r.completed_real + r.completed_bench) as f64,
        (r.completed_real + r.completed_bench) as f64 / elapsed
    );
}

fn main() {
    println!("== bench_hotpath ==");
    scheduling_decision_benches();
    simulator_throughput_bench();
}
