//! Hot-path microbenchmarks (criterion is unavailable offline; this is a
//! self-contained harness with warmup, repetition, and best-of-runs
//! reporting).
//!
//! Targets the paper's throughput claim: schedulers must sustain
//! "millions of tasks per second" with a *constant-work* decision loop.
//! All measurement code lives in `rosella::hotpath` (shared with the
//! `rosella hotpath` subcommand that emits `BENCH_hotpath.json`); this
//! binary runs it at n = 30 (the paper's testbed scale) and n = 256 so an
//! O(n) term in the decision path is visible as a slope, then adds the
//! full-learning-stack simulator run.

use rosella::cluster::{SpeedProfile, Volatility};
use rosella::hotpath::{
    alias_rebuild_bench, decision_bench, false_sharing_bench, metrics_overhead_bench, sim_bench,
    HotpathReport,
};
use rosella::learner::LearnerConfig;
use rosella::scheduler::{PolicyKind, TieRule};
use rosella::simulator::{run, SimConfig};
use rosella::workload::WorkloadKind;
use std::time::Instant;

fn full_learning_stack_bench() {
    // With the learning stack enabled (publishes + benchmark jobs).
    let cfg = SimConfig {
        seed: 3,
        duration: 60.0,
        warmup: 0.0,
        speeds: SpeedProfile::S1,
        volatility: Volatility::Permute { period: 30.0 },
        workload: WorkloadKind::Synthetic,
        load: 0.8,
        policy: PolicyKind::PPoT { tie: TieRule::Sq2, late_binding: false },
        learner: LearnerConfig::default(),
        queue_sample: None,
        timeline: None,
    };
    let start = Instant::now();
    let r = run(cfg);
    let elapsed = start.elapsed().as_secs_f64();
    println!(
        "sim full learning stack: {:>8.0} tasks in {elapsed:.3}s wall ({:.0} tasks/s)",
        (r.completed_real + r.completed_bench) as f64,
        (r.completed_real + r.completed_bench) as f64 / elapsed
    );
}

fn main() {
    println!("== bench_hotpath ==");
    let sizes = vec![30usize, 256];
    let report = HotpathReport {
        decisions: decision_bench(&sizes, 2_000_000, 3),
        rebuilds: alias_rebuild_bench(&sizes, 200_000, 3),
        sims: sim_bench(&sizes, 60.0),
        planes: Vec::new(), // bench_plane owns the plane sweep
        metrics_overhead: Some(metrics_overhead_bench(256, 2_000_000, 3)),
        topology: None, // the plane half lives in bench_plane; pair printed below
        sizes,
    };
    print!("{}", report.render());
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4).clamp(2, 8);
    let (unpadded_ns, padded_ns) = false_sharing_bench(threads, 2_000_000, 3);
    println!(
        "probe false sharing ({threads} threads): packed {unpadded_ns:.1} ns  \
         padded {padded_ns:.1} ns  ratio {:.3}x",
        unpadded_ns / padded_ns
    );
    full_learning_stack_bench();
}
