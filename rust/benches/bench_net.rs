//! Cross-process vs in-process plane: what does the wire cost?
//!
//! Runs the same workload twice — the in-process sharded plane
//! (`plane::run_plane`, per-shard learners) and the loopback cross-process
//! plane (pool server + k TCP frontends) — and reports aggregate task
//! throughput and merge counts side by side. The acceptance bar from the
//! roadmap is comparability, not parity: the net plane pays one RTT of
//! probe staleness per beat, which this harness makes visible.
//!
//! `cargo bench --bench bench_net`

use rosella::learner::SyncPolicyConfig;
use rosella::net::{run_remote_frontend, ConnectConfig, NetServer, NetServerConfig};
use rosella::plane::{run_plane, LearnerMode, PlaneConfig};
use std::thread;

fn in_process(k: usize, cfg: &NetServerConfig) -> (f64, u64, u64) {
    // Every knob the net side runs with is forwarded, so the two planes
    // execute the same workload under the same policy — the ratio below
    // isolates the wire cost, nothing else.
    let policy = match rosella::scheduler::PolicyKind::parse(&cfg.policy) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bad policy '{}': {e}", cfg.policy);
            std::process::exit(2);
        }
    };
    let plane = PlaneConfig {
        speeds: cfg.speeds.clone(),
        frontends: k,
        policy,
        rate: cfg.rate,
        duration: cfg.duration,
        mean_demand: cfg.mean_demand,
        batch: cfg.batch,
        seed: cfg.seed,
        publish_interval: cfg.publish_interval,
        warmup: cfg.warmup,
        fake_jobs: cfg.fake_jobs,
        learners: LearnerMode::PerShard,
        sync_interval: cfg.sync_interval,
        sync_policy: cfg.sync_policy,
        ..PlaneConfig::default()
    };
    match run_plane(plane) {
        Ok(r) => (r.completed as f64 / r.elapsed, r.completed, r.sync_merges),
        Err(e) => {
            eprintln!("in-process plane failed: {e}");
            std::process::exit(2);
        }
    }
}

fn cross_process(k: usize, cfg: &NetServerConfig) -> (f64, u64, u64) {
    let mut cfg = cfg.clone();
    cfg.frontends = k;
    let server = match NetServer::bind(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind failed: {e}");
            std::process::exit(2);
        }
    };
    let addr = server.local_addr().expect("local addr").to_string();
    let server_handle = thread::spawn(move || server.serve());
    let frontends: Vec<_> = (0..k)
        .map(|shard| {
            let addr = addr.clone();
            thread::spawn(move || run_remote_frontend(&ConnectConfig::new(addr, shard, k)))
        })
        .collect();
    for h in frontends {
        if let Err(e) = h.join().expect("frontend thread") {
            eprintln!("frontend failed: {e}");
            std::process::exit(2);
        }
    }
    match server_handle.join().expect("server thread") {
        Ok(r) => (r.tasks_per_sec, r.completed, r.sync_merges),
        Err(e) => {
            eprintln!("server failed: {e}");
            std::process::exit(2);
        }
    }
}

fn main() {
    let base = NetServerConfig {
        listen: "127.0.0.1:0".into(),
        speeds: vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
        rate: 400.0,
        duration: 2.0,
        mean_demand: 0.005,
        sync_interval: 0.2,
        sync_policy: SyncPolicyConfig::periodic(),
        ..NetServerConfig::default()
    };
    println!("-- in-process vs cross-process plane ({} workers) --", base.speeds.len());
    println!("k   in-proc tasks/s   net tasks/s   ratio   in-proc merges   net merges");
    for k in [1usize, 2, 4] {
        let (ip_rate, _, ip_merges) = in_process(k, &base);
        let (net_rate, net_done, net_merges) = cross_process(k, &base);
        println!(
            "{k}   {ip_rate:>15.0}   {net_rate:>11.0}   {:>5.2}   {ip_merges:>14}   {net_merges:>10}",
            net_rate / ip_rate.max(1.0)
        );
        assert!(net_done > 0, "cross-process run completed nothing at k={k}");
    }
}
