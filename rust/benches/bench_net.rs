//! Cross-process vs in-process plane: what does the wire cost?
//!
//! Three experiments, one harness:
//!
//! 1. **Comparable pair** — the same paced workload run twice, on the
//!    in-process sharded plane (`plane::run_plane`, per-shard learners)
//!    and on the loopback cross-process plane (pool server + k TCP
//!    frontends). At an arrival-paced rate both planes should keep up,
//!    so the net/in-process ratio is the CI gate that the wire layer
//!    does not eat the schedule (roadmap bar: ratio ≥ 0.6).
//!
//! 2. **Coalescing sweep** — the cross-process plane alone, offered a
//!    saturating arrival rate so throughput is limited by the dispatch
//!    path itself, swept over the submit-coalescing batch size
//!    B ∈ {1, 8, 64, 256}. B=1 is the eager one-frame-per-task protocol
//!    (one ~33-byte frame and one write syscall per task); larger B
//!    amortizes headers and syscalls across a `SubmitBatch` frame. The
//!    CI gate: batched (B ≥ 64) must move ≥ 2× the tasks/sec of B=1
//!    within the same run of this binary.
//!
//! 3. **Poll-shard headline** — four frontends at the same saturating
//!    offered rate, batched framing fixed at B=64, swept over the server
//!    poll-shard count P ∈ {1, 2, 4}. P=1 is the old single-poll-loop
//!    data plane; P ≥ 2 splits the connections across topology-pinned
//!    epoll shards. The CI gate is the headline of this PR: the best
//!    sharded point (P ∈ {2, 4}) must move ≥ 1.2× the tasks/sec of P=1
//!    within the same run of this binary.
//!
//! `cargo bench --bench bench_net -- --json BENCH_net.json`

use rosella::config::{to_string, Json};
use rosella::learner::SyncPolicyConfig;
use rosella::net::{run_remote_frontend, ConnectConfig, NetServer, NetServerConfig};
use rosella::plane::{run_plane, LearnerMode, PlaneConfig};
use std::collections::BTreeMap;
use std::thread;

fn in_process(k: usize, cfg: &NetServerConfig) -> (f64, u64, u64) {
    // Every knob the net side runs with is forwarded, so the two planes
    // execute the same workload under the same policy — the ratio below
    // isolates the wire cost, nothing else.
    let policy = match rosella::scheduler::PolicyKind::parse(&cfg.policy) {
        Ok(p) => p,
        Err(e) => {
            eprintln!("bad policy '{}': {e}", cfg.policy);
            std::process::exit(2);
        }
    };
    let plane = PlaneConfig {
        speeds: cfg.speeds.clone(),
        frontends: k,
        policy,
        rate: cfg.rate,
        duration: cfg.duration,
        mean_demand: cfg.mean_demand,
        batch: cfg.batch,
        seed: cfg.seed,
        publish_interval: cfg.publish_interval,
        warmup: cfg.warmup,
        fake_jobs: cfg.fake_jobs,
        learners: LearnerMode::PerShard,
        sync_interval: cfg.sync_interval,
        sync_policy: cfg.sync_policy,
        ..PlaneConfig::default()
    };
    match run_plane(plane) {
        Ok(r) => (r.completed as f64 / r.elapsed, r.completed, r.sync_merges),
        Err(e) => {
            eprintln!("in-process plane failed: {e}");
            std::process::exit(2);
        }
    }
}

/// One loopback cross-process run; `net_batch` overrides the
/// server-advertised coalescing batch on every frontend (`Some(1)` forces
/// the eager one-frame-per-task protocol, `None` accepts the server's B).
fn cross_process(
    k: usize,
    cfg: &NetServerConfig,
    net_batch: Option<usize>,
) -> (f64, u64, u64, u64) {
    let mut cfg = cfg.clone();
    cfg.frontends = k;
    let server = match NetServer::bind(cfg) {
        Ok(s) => s,
        Err(e) => {
            eprintln!("bind failed: {e}");
            std::process::exit(2);
        }
    };
    let addr = server.local_addr().expect("local addr").to_string();
    let server_handle = thread::spawn(move || server.serve());
    let frontends: Vec<_> = (0..k)
        .map(|shard| {
            let addr = addr.clone();
            thread::spawn(move || {
                let mut ccfg = ConnectConfig::new(addr, shard, k);
                ccfg.net_batch = net_batch;
                run_remote_frontend(&ccfg)
            })
        })
        .collect();
    for h in frontends {
        if let Err(e) = h.join().expect("frontend thread") {
            eprintln!("frontend failed: {e}");
            std::process::exit(2);
        }
    }
    match server_handle.join().expect("server thread") {
        Ok(r) => (r.tasks_per_sec, r.completed, r.sync_merges, r.poll_wakeups),
        Err(e) => {
            eprintln!("server failed: {e}");
            std::process::exit(2);
        }
    }
}

fn main() {
    // Benches are harness = false binaries: `cargo bench` still forwards
    // libtest-style flags (e.g. `--bench`), so only `--json PATH` is ours
    // and everything else is ignored.
    let mut json_path: Option<String> = None;
    let mut argv = std::env::args().skip(1);
    while let Some(a) = argv.next() {
        if a == "--json" {
            json_path = Some(argv.next().unwrap_or_else(|| {
                eprintln!("--json needs a path");
                std::process::exit(2);
            }));
        }
    }

    // -- experiment 1: comparable pair at a paced (non-saturating) rate --
    let base = NetServerConfig {
        listen: "127.0.0.1:0".into(),
        speeds: vec![2.0, 1.0, 1.0, 1.0, 0.5, 0.5, 0.25, 0.25],
        rate: 400.0,
        duration: 2.0,
        mean_demand: 0.005,
        sync_interval: 0.2,
        sync_policy: SyncPolicyConfig::periodic(),
        ..NetServerConfig::default()
    };
    println!("-- in-process vs cross-process plane ({} workers) --", base.speeds.len());
    println!("k   in-proc tasks/s   net tasks/s   ratio   in-proc merges   net merges");
    let mut comparable: Option<(f64, f64)> = None;
    for k in [1usize, 2, 4] {
        let (ip_rate, _, ip_merges) = in_process(k, &base);
        let (net_rate, net_done, net_merges, _) = cross_process(k, &base, None);
        println!(
            "{k}   {ip_rate:>15.0}   {net_rate:>11.0}   {:>5.2}   {ip_merges:>14}   {net_merges:>10}",
            net_rate / ip_rate.max(1.0)
        );
        assert!(net_done > 0, "cross-process run completed nothing at k={k}");
        if k == 2 {
            comparable = Some((ip_rate, net_rate));
        }
    }
    let (comp_ip, comp_net) = comparable.expect("k=2 ran");

    // -- experiment 2: coalescing sweep at a saturating offered rate --
    //
    // The offered rate is far above what one frontend can dispatch, so the
    // arrival loop runs flat out and tasks/sec measures the per-task cost
    // of the dispatch path (decision + encode + write). Demand is tiny and
    // the pool wide so the post-stop drain stays bounded; `tasks_per_sec`
    // divides by the pre-drain elapsed either way.
    let sweep_base = NetServerConfig {
        listen: "127.0.0.1:0".into(),
        speeds: vec![8.0; 32],
        rate: 1.5e6,
        duration: 0.5,
        mean_demand: 0.0004,
        batch: 1024,
        sync_interval: 0.2,
        sync_policy: SyncPolicyConfig::periodic(),
        ..NetServerConfig::default()
    };
    const BATCHES: [usize; 4] = [1, 8, 64, 256];
    println!();
    println!(
        "-- submit coalescing sweep (1 frontend, {} workers, saturating arrivals) --",
        sweep_base.speeds.len()
    );
    println!("B     net tasks/s   completed   speedup vs B=1");
    let mut points: Vec<(usize, f64, u64)> = Vec::new();
    for b in BATCHES {
        let (rate, done, _, _) = cross_process(1, &sweep_base, Some(b));
        assert!(done > 0, "sweep run completed nothing at B={b}");
        let b1 = points.first().map_or(rate, |&(_, r, _)| r);
        println!("{b:<5} {rate:>11.0}   {done:>9}   {:>13.2}", rate / b1.max(1.0));
        points.push((b, rate, done));
    }
    let eager = points[0].1;
    let batched = points
        .iter()
        .filter(|&&(b, _, _)| b >= 64)
        .map(|&(_, r, _)| r)
        .fold(0.0_f64, f64::max);
    let speedup = batched / eager.max(1.0);
    println!();
    println!(
        "batched (B>=64) vs eager (B=1): {batched:.0} vs {eager:.0} tasks/s ({speedup:.2}x)"
    );

    // -- experiment 3: poll-shard headline at a saturating offered rate --
    //
    // Four frontends hammer the pool with batched (B=64) framing — enough
    // concurrent connections that a single poll shard is the serialization
    // point — while the server's data plane is swept over P poll shards.
    // P=1 reproduces the old single-poll-loop plane inside the new code;
    // P >= 2 is the sharded epoll plane this PR lands.
    let headline_base = NetServerConfig {
        listen: "127.0.0.1:0".into(),
        speeds: vec![8.0; 32],
        rate: 1.5e6,
        duration: 0.5,
        mean_demand: 0.0004,
        batch: 1024,
        sync_interval: 0.2,
        sync_policy: SyncPolicyConfig::periodic(),
        ..NetServerConfig::default()
    };
    const SHARDS: [usize; 3] = [1, 2, 4];
    println!();
    println!(
        "-- poll-shard headline (4 frontends, {} workers, B=64, saturating arrivals) --",
        headline_base.speeds.len()
    );
    println!("P     net tasks/s   completed   wakeups   speedup vs P=1");
    let mut shard_points: Vec<(usize, f64, u64, u64)> = Vec::new();
    for p in SHARDS {
        let mut cfg = headline_base.clone();
        cfg.poll_shards = Some(p);
        let (rate, done, _, wakeups) = cross_process(4, &cfg, Some(64));
        assert!(done > 0, "headline run completed nothing at P={p}");
        let p1 = shard_points.first().map_or(rate, |&(_, r, _, _)| r);
        println!(
            "{p:<5} {rate:>11.0}   {done:>9}   {wakeups:>7}   {:>13.2}",
            rate / p1.max(1.0)
        );
        shard_points.push((p, rate, done, wakeups));
    }
    let single = shard_points[0].1;
    let best_sharded = shard_points
        .iter()
        .filter(|&&(p, _, _, _)| p >= 2)
        .map(|&(_, r, _, _)| r)
        .fold(0.0_f64, f64::max);
    let sharded_ratio = best_sharded / single.max(1.0);
    println!();
    println!(
        "best sharded (P in {{2,4}}) vs single shard: {best_sharded:.0} vs {single:.0} tasks/s ({sharded_ratio:.2}x)"
    );

    if let Some(path) = json_path {
        let mut comp = BTreeMap::new();
        comp.insert("frontends".into(), Json::Num(2.0));
        comp.insert("workers".into(), Json::Num(base.speeds.len() as f64));
        comp.insert("rate".into(), Json::Num(base.rate));
        comp.insert("duration".into(), Json::Num(base.duration));
        comp.insert("in_process_tasks_per_sec".into(), Json::Num(comp_ip.round()));
        comp.insert("net_tasks_per_sec".into(), Json::Num(comp_net.round()));
        comp.insert("ratio".into(), Json::Num(comp_net / comp_ip.max(1.0)));
        let pts: Vec<Json> = points
            .iter()
            .map(|&(b, rate, done)| {
                let mut m = BTreeMap::new();
                m.insert("net_batch".into(), Json::Num(b as f64));
                m.insert("tasks_per_sec".into(), Json::Num(rate.round()));
                m.insert("completed".into(), Json::Num(done as f64));
                Json::Obj(m)
            })
            .collect();
        let mut sweep = BTreeMap::new();
        sweep.insert("frontends".into(), Json::Num(1.0));
        sweep.insert("workers".into(), Json::Num(sweep_base.speeds.len() as f64));
        sweep.insert("rate".into(), Json::Num(sweep_base.rate));
        sweep.insert("duration".into(), Json::Num(sweep_base.duration));
        sweep.insert("points".into(), Json::Arr(pts));
        sweep.insert("speedup_batched".into(), Json::Num(speedup));
        let hpts: Vec<Json> = shard_points
            .iter()
            .map(|&(p, rate, done, wakeups)| {
                let mut m = BTreeMap::new();
                m.insert("poll_shards".into(), Json::Num(p as f64));
                m.insert("tasks_per_sec".into(), Json::Num(rate.round()));
                m.insert("completed".into(), Json::Num(done as f64));
                m.insert("wakeups".into(), Json::Num(wakeups as f64));
                Json::Obj(m)
            })
            .collect();
        let mut headline = BTreeMap::new();
        headline.insert("frontends".into(), Json::Num(4.0));
        headline.insert("workers".into(), Json::Num(headline_base.speeds.len() as f64));
        headline.insert("rate".into(), Json::Num(headline_base.rate));
        headline.insert("duration".into(), Json::Num(headline_base.duration));
        headline.insert("net_batch".into(), Json::Num(64.0));
        headline.insert("points".into(), Json::Arr(hpts));
        headline.insert("tasks_per_sec".into(), Json::Num(best_sharded.round()));
        headline.insert("sharded_ratio".into(), Json::Num(sharded_ratio));
        let mut top = BTreeMap::new();
        top.insert("bench".into(), Json::Str("net".into()));
        top.insert("policy".into(), Json::Str(base.policy.clone()));
        top.insert("seed".into(), Json::Num(base.seed as f64));
        top.insert("comparable".into(), Json::Obj(comp));
        top.insert("sweep".into(), Json::Obj(sweep));
        top.insert("headline".into(), Json::Obj(headline));
        if let Err(e) = std::fs::write(&path, to_string(&Json::Obj(top)) + "\n") {
            eprintln!("writing {path}: {e}");
            std::process::exit(2);
        }
        println!("wrote {path}");
    }
}
